//! TOML keys for the shared cloud tier and its elastic replica pool:
//! the `[cloud]` section maps onto [`CloudParams`] (plus the elastic
//! dispatch / admission / batch-schedule knobs of [`ElasticParams`]),
//! and `[cloud.autoscaler]` onto [`crate::cloudscale::AutoscalerParams`]
//! and its [`crate::cloudscale::ScalingRule`]. Both sections are
//! optional; unspecified keys keep the neutral defaults, so a config
//! file without them describes exactly the pre-elastic fixed cloud.
//!
//! ```toml
//! [cloud]
//! capacity_mmacs_per_s = 3.3e6
//! batch_window_s = 0.010
//! max_batch = 32
//! single_stream_efficiency = 0.30
//! max_backlog_s = 30.0
//! dispatch = "rr"            # rr | least
//! admit_backlog_s = 5.0      # omit for admission off
//! batch_schedule = "static"  # static | adaptive
//!
//! [cloud.autoscaler]
//! min_replicas = 1
//! max_replicas = 4
//! warmup_s = 20.0
//! up_utilization = 0.75
//! down_utilization = 0.30
//! up_queue_wait_s = 1.0
//! up_cooldown_s = 10.0
//! down_cooldown_s = 30.0
//! ```

use super::toml::TomlDoc;
use crate::cloudscale::{BatchSchedule, DispatchKind, ElasticParams};
use crate::fleet::CloudParams;

/// Build [`CloudParams`] from the `[cloud]` section (defaults when the
/// section or a key is absent). Values are validated the same way the
/// fleet CLI validates its flags.
pub fn cloud_params_from_doc(doc: &TomlDoc) -> anyhow::Result<CloudParams> {
    let mut p = CloudParams::default();
    if let Some(cloud) = doc.get("cloud") {
        if let Some(v) = cloud.get("capacity_mmacs_per_s").and_then(|v| v.as_f64()) {
            p.capacity_mmacs_per_s = v;
        }
        if let Some(v) = cloud.get("batch_window_s").and_then(|v| v.as_f64()) {
            p.batch_window_s = v;
        }
        if let Some(v) = cloud.get("max_batch").and_then(|v| v.as_i64()) {
            anyhow::ensure!(v >= 1, "cloud.max_batch must be >= 1");
            p.max_batch = v as usize;
        }
        if let Some(v) = cloud.get("single_stream_efficiency").and_then(|v| v.as_f64()) {
            p.single_stream_efficiency = v;
        }
        if let Some(v) = cloud.get("max_backlog_s").and_then(|v| v.as_f64()) {
            p.max_backlog_s = v;
        }
    }
    anyhow::ensure!(p.capacity_mmacs_per_s > 0.0, "cloud.capacity_mmacs_per_s must be > 0");
    anyhow::ensure!(p.batch_window_s > 0.0, "cloud.batch_window_s must be > 0");
    anyhow::ensure!(
        p.single_stream_efficiency > 0.0 && p.single_stream_efficiency <= 1.0,
        "cloud.single_stream_efficiency out of (0,1]"
    );
    anyhow::ensure!(p.max_backlog_s > 0.0, "cloud.max_backlog_s must be > 0");
    Ok(p)
}

/// Build [`ElasticParams`] from the elastic keys of `[cloud]` plus the
/// `[cloud.autoscaler]` section. With neither present this returns the
/// neutral default (one pinned replica, admission off, static batching).
pub fn elastic_params_from_doc(doc: &TomlDoc) -> anyhow::Result<ElasticParams> {
    let mut e = ElasticParams::default();
    if let Some(cloud) = doc.get("cloud") {
        if let Some(v) = cloud.get("dispatch").and_then(|v| v.as_str()) {
            e.dispatch = DispatchKind::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown cloud.dispatch '{v}' (rr|least)"))?;
        }
        if let Some(v) = cloud.get("admit_backlog_s").and_then(|v| v.as_f64()) {
            e.admit_backlog_s = v;
        }
        if let Some(v) = cloud.get("batch_schedule").and_then(|v| v.as_str()) {
            e.batch = BatchSchedule::parse(v).ok_or_else(|| {
                anyhow::anyhow!("unknown cloud.batch_schedule '{v}' (static|adaptive)")
            })?;
        }
    }
    if let Some(auto) = doc.get("cloud.autoscaler") {
        let a = &mut e.autoscaler;
        if let Some(v) = auto.get("min_replicas").and_then(|v| v.as_i64()) {
            a.min_replicas = v.max(0) as usize;
        }
        if let Some(v) = auto.get("max_replicas").and_then(|v| v.as_i64()) {
            a.max_replicas = v.max(0) as usize;
        }
        if let Some(v) = auto.get("warmup_s").and_then(|v| v.as_f64()) {
            a.warmup_s = v;
        }
        if let Some(v) = auto.get("up_utilization").and_then(|v| v.as_f64()) {
            a.rule.up_utilization = v;
        }
        if let Some(v) = auto.get("down_utilization").and_then(|v| v.as_f64()) {
            a.rule.down_utilization = v;
        }
        if let Some(v) = auto.get("up_queue_wait_s").and_then(|v| v.as_f64()) {
            a.rule.up_queue_wait_s = v;
        }
        if let Some(v) = auto.get("up_cooldown_s").and_then(|v| v.as_f64()) {
            a.rule.up_cooldown_s = v;
        }
        if let Some(v) = auto.get("down_cooldown_s").and_then(|v| v.as_f64()) {
            a.rule.down_cooldown_s = v;
        }
    }
    e.validate().map_err(|m| anyhow::anyhow!("elastic cloud: {m}"))?;
    Ok(e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configsys::toml::parse_toml;

    #[test]
    fn absent_sections_yield_neutral_defaults() {
        let doc = parse_toml("seed = 1\n").unwrap();
        let cloud = cloud_params_from_doc(&doc).unwrap();
        assert_eq!(cloud.max_batch, CloudParams::default().max_batch);
        let elastic = elastic_params_from_doc(&doc).unwrap();
        assert!(elastic.is_neutral());
    }

    #[test]
    fn full_cloud_sections_round_trip() {
        let doc = parse_toml(
            r#"
[cloud]
capacity_mmacs_per_s = 5000.0
batch_window_s = 0.02
max_batch = 16
single_stream_efficiency = 0.4
max_backlog_s = 10.0
dispatch = "least"
admit_backlog_s = 5.0
batch_schedule = "adaptive"

[cloud.autoscaler]
min_replicas = 2
max_replicas = 6
warmup_s = 8.0
up_utilization = 0.8
down_utilization = 0.2
up_queue_wait_s = 0.5
up_cooldown_s = 4.0
down_cooldown_s = 12.0
"#,
        )
        .unwrap();
        let cloud = cloud_params_from_doc(&doc).unwrap();
        assert_eq!(cloud.capacity_mmacs_per_s, 5000.0);
        assert_eq!(cloud.batch_window_s, 0.02);
        assert_eq!(cloud.max_batch, 16);
        assert_eq!(cloud.single_stream_efficiency, 0.4);
        assert_eq!(cloud.max_backlog_s, 10.0);
        let e = elastic_params_from_doc(&doc).unwrap();
        assert!(!e.is_neutral());
        assert_eq!(e.dispatch, DispatchKind::LeastBacklog);
        assert_eq!(e.admit_backlog_s, 5.0);
        assert_eq!(e.batch, BatchSchedule::Adaptive);
        assert_eq!(e.autoscaler.min_replicas, 2);
        assert_eq!(e.autoscaler.max_replicas, 6);
        assert_eq!(e.autoscaler.warmup_s, 8.0);
        assert_eq!(e.autoscaler.rule.up_utilization, 0.8);
        assert_eq!(e.autoscaler.rule.down_utilization, 0.2);
        assert_eq!(e.autoscaler.rule.up_queue_wait_s, 0.5);
        assert_eq!(e.autoscaler.rule.up_cooldown_s, 4.0);
        assert_eq!(e.autoscaler.rule.down_cooldown_s, 12.0);
    }

    #[test]
    fn invalid_cloud_values_are_rejected() {
        for text in [
            "[cloud]\ncapacity_mmacs_per_s = 0.0\n",
            "[cloud]\nbatch_window_s = -1.0\n",
            "[cloud]\nmax_batch = 0\n",
            "[cloud]\nsingle_stream_efficiency = 1.5\n",
            "[cloud]\nmax_backlog_s = 0.0\n",
        ] {
            let doc = parse_toml(text).unwrap();
            assert!(cloud_params_from_doc(&doc).is_err(), "{text} must be rejected");
        }
        for text in [
            "[cloud]\ndispatch = \"random\"\n",
            "[cloud]\nbatch_schedule = \"wide\"\n",
            "[cloud]\nadmit_backlog_s = 0.0\n",
            "[cloud.autoscaler]\nmin_replicas = 0\n",
            "[cloud.autoscaler]\nmin_replicas = 4\nmax_replicas = 2\n",
            "[cloud.autoscaler]\nup_utilization = 0.2\ndown_utilization = 0.5\n",
            "[cloud.autoscaler]\nwarmup_s = -2.0\n",
        ] {
            let doc = parse_toml(text).unwrap();
            assert!(elastic_params_from_doc(&doc).is_err(), "{text} must be rejected");
        }
    }
}
