//! Core shared types: execution sites, processors, precisions and actions.
//!
//! An [`Action`] is the paper's RL action — an execution target: which site
//! (local device / connected edge / cloud), which processor on that site,
//! at which DVFS V/F step, with which quantization precision (§4.1 "Action",
//! augmented per §5.3 with DVFS and quantization knobs).

use std::fmt;

/// Where the inference runs (scale-up on-device vs scale-out).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Site {
    /// On the mobile device itself.
    Local,
    /// A nearby device reachable over the peer-to-peer link (Wi-Fi Direct).
    ConnectedEdge,
    /// The cloud server over the WLAN link.
    Cloud,
}

impl Site {
    pub const ALL: [Site; 3] = [Site::Local, Site::ConnectedEdge, Site::Cloud];
}

impl fmt::Display for Site {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Site::Local => write!(f, "local"),
            Site::ConnectedEdge => write!(f, "connected-edge"),
            Site::Cloud => write!(f, "cloud"),
        }
    }
}

/// Processor classes present in the edge-cloud fleet (paper Table 2 + §5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProcKind {
    Cpu,
    Gpu,
    Dsp,
}

impl ProcKind {
    pub const ALL: [ProcKind; 3] = [ProcKind::Cpu, ProcKind::Gpu, ProcKind::Dsp];
}

impl fmt::Display for ProcKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProcKind::Cpu => write!(f, "cpu"),
            ProcKind::Gpu => write!(f, "gpu"),
            ProcKind::Dsp => write!(f, "dsp"),
        }
    }
}

/// Quantization precision of the deployed executable (§2.2, §5.3).
///
/// Paper mapping: CPU supports FP32+INT8, GPU FP32+FP16, DSP INT8 only.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
}

impl Precision {
    pub const ALL: [Precision; 3] = [Precision::Fp32, Precision::Fp16, Precision::Int8];

    /// Artifact suffix used by `aot.py` (`<model>_<precision>.hlo.txt`).
    pub fn artifact_tag(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    /// Bytes per weight element — drives the memory-bandwidth side of the
    /// latency model (INT8 executables move 4x fewer weight bytes).
    pub fn weight_bytes(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
            Precision::Int8 => 1.0,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.artifact_tag())
    }
}

/// DNN partition point of an execution plan (§7 Neurosurgeon-class
/// split computing, promoted to a first-class action dimension).
///
/// `Mono` is today's semantics — the whole network runs at
/// [`Action::site`]. `At(k)` indexes an *interior* point of
/// [`crate::exec::split::SPLIT_POINTS`] (1..=3): layers up to
/// `SPLIT_POINTS[k]` run on the local device, the activation ships over
/// the WLAN and the tail finishes on the cloud. `Mono` sorts first so
/// all-Mono catalogues keep their pre-refactor relative order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SplitPoint {
    /// No partition: the whole network runs at the action's site.
    Mono,
    /// Split at `SPLIT_POINTS[k]`: head local, tail on the cloud.
    At(u8),
}

impl SplitPoint {
    /// Is this a partitioned plan (head local, tail over the WLAN)?
    pub fn is_split(self) -> bool {
        matches!(self, SplitPoint::At(_))
    }
}

/// One execution-scaling decision (the RL action): an execution *plan* —
/// site, processor, DVFS step, precision, and (optionally) a DNN
/// partition point.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Action {
    pub site: Site,
    pub proc: ProcKind,
    /// DVFS step index into the processor's V/F table; 0 = max frequency.
    /// Remote sites run at a fixed operating point; use 0.
    pub vf_step: u8,
    pub precision: Precision,
    /// DNN partition point. `Mono` (the default everywhere) preserves the
    /// pre-partition semantics; `At(k)` runs the head locally on
    /// (`proc`, `vf_step`, `precision`) and the tail on the cloud.
    pub split: SplitPoint,
}

impl Action {
    pub fn new(site: Site, proc: ProcKind, vf_step: u8, precision: Precision) -> Self {
        Action { site, proc, vf_step, precision, split: SplitPoint::Mono }
    }

    /// Shorthand for the common "max frequency" actions.
    pub fn local(proc: ProcKind, precision: Precision) -> Self {
        Action::new(Site::Local, proc, 0, precision)
    }

    pub fn cloud() -> Self {
        Action::new(Site::Cloud, ProcKind::Gpu, 0, Precision::Fp32)
    }

    pub fn connected_edge() -> Self {
        Action::new(Site::ConnectedEdge, ProcKind::Gpu, 0, Precision::Fp16)
    }

    /// A partitioned plan: head on the local (`proc`, `precision`) at max
    /// frequency, tail on the cloud. `k` indexes
    /// [`crate::exec::split::SPLIT_POINTS`] and must be interior (1..=3).
    pub fn split_at(k: u8, proc: ProcKind, precision: Precision) -> Self {
        Action {
            site: Site::Local,
            proc,
            vf_step: 0,
            precision,
            split: SplitPoint::At(k),
        }
    }

    /// Does this plan put traffic on the cloud's WLAN leg? True for a
    /// monolithic cloud offload *and* for any split plan — both must be
    /// priced with the cloud's congestion view, both are rejected while
    /// admission control fast-fails, and both count as cloud load.
    pub fn uses_cloud(&self) -> bool {
        self.site == Site::Cloud || self.split.is_split()
    }
}

impl fmt::Display for Action {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}/{}@vf{}/{}",
            self.site, self.proc, self.vf_step, self.precision
        )?;
        // Mono renders exactly the pre-partition grammar, so default
        // traces/logs stay byte-identical.
        if let SplitPoint::At(k) = self.split {
            write!(f, "+split{k}")?;
        }
        Ok(())
    }
}

/// Which physical device a simulated run is anchored on (paper §5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DeviceId {
    /// Xiaomi Mi 8 Pro — high-end, CPU+GPU+DSP.
    Mi8Pro,
    /// Samsung Galaxy S10e — high-end, CPU+GPU (no DSP).
    GalaxyS10e,
    /// Motorola Moto X Force — mid-end, CPU+GPU.
    MotoXForce,
    /// Samsung Galaxy Tab S6 — the locally connected edge device.
    TabS6,
    /// Xeon E5-2640 + P100 — the cloud server.
    CloudServer,
}

impl DeviceId {
    /// The three handsets the paper evaluates on.
    pub const PHONES: [DeviceId; 3] =
        [DeviceId::Mi8Pro, DeviceId::GalaxyS10e, DeviceId::MotoXForce];
}

impl fmt::Display for DeviceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DeviceId::Mi8Pro => write!(f, "Mi8Pro"),
            DeviceId::GalaxyS10e => write!(f, "GalaxyS10e"),
            DeviceId::MotoXForce => write!(f, "MotoXForce"),
            DeviceId::TabS6 => write!(f, "TabS6"),
            DeviceId::CloudServer => write!(f, "CloudServer"),
        }
    }
}

/// Outcome of one executed inference — the measurements the reward consumes.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// End-to-end latency seen by the requesting app (seconds).
    pub latency_s: f64,
    /// Estimated energy per Eq.(1)-(4) (joules) — what the agent sees.
    pub energy_est_j: f64,
    /// "Ground-truth" simulator energy (joules) — for estimator MAPE only.
    pub energy_true_j: f64,
    /// Top-1 accuracy of the deployed (NN, precision, site) combination.
    pub accuracy: f64,
    /// A remote action was attempted over a disconnected link and timed
    /// out: no result was produced, yet the TX energy and the timeout
    /// latency were still charged to the device.
    pub remote_failed: bool,
}

impl Measurement {
    /// Performance-per-watt in the paper's sense: inferences/sec/watt
    /// = 1 / (latency * power) = 1 / energy ... per inference.
    pub fn ppw(&self) -> f64 {
        if self.energy_true_j <= 0.0 {
            0.0
        } else {
            1.0 / self.energy_true_j
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn action_display_roundtrip_fields() {
        let a = Action::local(ProcKind::Gpu, Precision::Fp16);
        assert_eq!(a.site, Site::Local);
        assert_eq!(format!("{a}"), "local/gpu@vf0/fp16");
    }

    #[test]
    fn split_action_display_appends_suffix() {
        let a = Action::split_at(2, ProcKind::Dsp, Precision::Int8);
        assert_eq!(a.site, Site::Local);
        assert_eq!(a.split, SplitPoint::At(2));
        assert!(a.uses_cloud(), "a split plan has a cloud leg");
        assert_eq!(format!("{a}"), "local/dsp@vf0/int8+split2");
    }

    #[test]
    fn mono_actions_do_not_use_cloud_unless_sited_there() {
        assert!(!Action::local(ProcKind::Cpu, Precision::Fp32).uses_cloud());
        assert!(!Action::connected_edge().uses_cloud());
        assert!(Action::cloud().uses_cloud());
    }

    #[test]
    fn mono_sorts_before_any_split() {
        let mono = Action::local(ProcKind::Cpu, Precision::Fp32);
        let split = Action::split_at(1, ProcKind::Cpu, Precision::Fp32);
        assert!(mono < split, "Mono must sort first so default catalogues keep order");
        assert!(SplitPoint::Mono < SplitPoint::At(0));
    }

    #[test]
    fn precision_bytes_ordered() {
        assert!(Precision::Fp32.weight_bytes() > Precision::Fp16.weight_bytes());
        assert!(Precision::Fp16.weight_bytes() > Precision::Int8.weight_bytes());
    }

    #[test]
    fn ppw_is_inverse_energy() {
        let m = Measurement {
            latency_s: 0.01,
            energy_est_j: 0.5,
            energy_true_j: 0.5,
            accuracy: 0.7,
            remote_failed: false,
        };
        assert!((m.ppw() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ppw_zero_energy_guarded() {
        let m = Measurement {
            latency_s: 0.0,
            energy_est_j: 0.0,
            energy_true_j: 0.0,
            accuracy: 0.0,
            remote_failed: false,
        };
        assert_eq!(m.ppw(), 0.0);
    }
}
