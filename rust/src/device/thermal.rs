//! Thermal throttling model.
//!
//! §3.2: "frequent thermal throttling from high CPU utilization" degrades
//! CPU energy efficiency when a CPU-intensive app co-runs. We model a
//! first-order thermal RC: heat accumulates with dissipated power, and when
//! the virtual temperature crosses the throttle threshold the governor caps
//! the frequency ratio, which the latency model consumes.

/// First-order thermal state for one mobile device.
#[derive(Clone, Debug)]
pub struct ThermalState {
    /// Virtual temperature above ambient (K).
    temp_k: f64,
    /// Thermal resistance (K/W) — how much steady power heats the SoC.
    r_kw: f64,
    /// Time constant (s) of the exponential approach.
    tau_s: f64,
    /// Throttle threshold above ambient (K).
    threshold_k: f64,
    /// Frequency cap applied while throttling (ratio of max).
    throttle_ratio: f64,
}

impl Default for ThermalState {
    fn default() -> Self {
        // ~8 K/W, 30 s time constant, throttle at +22 K, cap to 70% —
        // representative of sustained-load behaviour on passively cooled
        // handsets.
        ThermalState {
            temp_k: 0.0,
            r_kw: 8.0,
            tau_s: 30.0,
            threshold_k: 22.0,
            throttle_ratio: 0.7,
        }
    }
}

impl ThermalState {
    pub fn new(r_kw: f64, tau_s: f64, threshold_k: f64, throttle_ratio: f64) -> Self {
        ThermalState { temp_k: 0.0, r_kw, tau_s, threshold_k, throttle_ratio }
    }

    /// Advance the thermal state by `dt` seconds with `power_w` dissipated.
    pub fn advance(&mut self, power_w: f64, dt: f64) {
        assert!(dt >= 0.0);
        let steady = self.r_kw * power_w.max(0.0);
        let alpha = 1.0 - (-dt / self.tau_s).exp();
        self.temp_k += (steady - self.temp_k) * alpha;
    }

    /// Currently throttling?
    pub fn throttled(&self) -> bool {
        self.temp_k >= self.threshold_k
    }

    /// Frequency multiplier the governor currently allows (1.0 or the cap).
    pub fn freq_cap(&self) -> f64 {
        if self.throttled() {
            self.throttle_ratio
        } else {
            1.0
        }
    }

    pub fn temperature_k(&self) -> f64 {
        self.temp_k
    }

    pub fn reset(&mut self) {
        self.temp_k = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_start_not_throttled() {
        let t = ThermalState::default();
        assert!(!t.throttled());
        assert_eq!(t.freq_cap(), 1.0);
    }

    #[test]
    fn sustained_high_power_throttles() {
        let mut t = ThermalState::default();
        // 5.5 W sustained (Mi8Pro CPU peak) -> steady 44 K >> 22 K threshold
        for _ in 0..120 {
            t.advance(5.5, 1.0);
        }
        assert!(t.throttled());
        assert!((t.freq_cap() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn low_power_never_throttles() {
        let mut t = ThermalState::default();
        for _ in 0..600 {
            t.advance(1.0, 1.0); // steady 8 K < 22 K
        }
        assert!(!t.throttled());
    }

    #[test]
    fn cools_down_when_idle() {
        let mut t = ThermalState::default();
        for _ in 0..120 {
            t.advance(5.5, 1.0);
        }
        assert!(t.throttled());
        for _ in 0..300 {
            t.advance(0.1, 1.0);
        }
        assert!(!t.throttled());
    }

    #[test]
    fn approach_is_exponential() {
        let mut t = ThermalState::default();
        t.advance(2.0, 30.0); // one time constant toward 16 K
        let one_tau = t.temperature_k();
        assert!((one_tau - 16.0 * (1.0 - (-1.0f64).exp())).abs() < 1e-9);
    }
}
