//! The paper's testbed fleet (Table 2 + §5.1) as device presets.
//!
//! Peak-power numbers and V/F step counts come straight from Table 2;
//! compute rates are calibrated so the relative orderings of Figs. 2-6
//! reproduce (high-end ≈ 3-4x the mid-end phone, tablet above the phones,
//! cloud another ~20x up with network cost on top).

use crate::types::{DeviceId, Precision, ProcKind};

use super::processor::{Device, Processor};

/// Build one device preset.
pub fn device(id: DeviceId) -> Device {
    match id {
        // Xiaomi Mi 8 Pro: Cortex-A75 2.8 GHz / 23 steps / 5.5 W,
        // Adreno 630 0.7 GHz / 7 steps / 2.8 W, Hexagon 685 DSP 1.8 W.
        DeviceId::Mi8Pro => Device {
            id,
            processors: vec![
                Processor {
                    kind: ProcKind::Cpu,
                    name: "Cortex-A75",
                    vf: Processor::vf_table(23, 0.8, 2.8, 0.7, 5.5),
                    idle_power_w: 0.12,
                    peak_gmacs: 38.0,
                    mem_bw_gbs: 14.0,
                    precisions: vec![Precision::Fp32, Precision::Int8],
                    dispatch_overhead_us: 15.0,
                },
                Processor {
                    kind: ProcKind::Gpu,
                    name: "Adreno-630",
                    vf: Processor::vf_table(7, 0.25, 0.7, 0.6, 2.8),
                    idle_power_w: 0.08,
                    peak_gmacs: 110.0,
                    mem_bw_gbs: 14.0,
                    precisions: vec![Precision::Fp32, Precision::Fp16],
                    dispatch_overhead_us: 120.0,
                },
                Processor {
                    kind: ProcKind::Dsp,
                    name: "Hexagon-685",
                    vf: Processor::vf_table(1, 1.0, 1.0, 1.8, 1.8),
                    idle_power_w: 0.05,
                    peak_gmacs: 180.0,
                    mem_bw_gbs: 12.0,
                    precisions: vec![Precision::Int8],
                    dispatch_overhead_us: 200.0,
                },
            ],
            dram_gb: 6.0,
            is_mobile: true,
        },
        // Samsung Galaxy S10e: Mongoose 2.7 GHz / 21 steps / 5.6 W,
        // Mali-G76 0.7 GHz / 9 steps / 2.4 W, no DSP.
        DeviceId::GalaxyS10e => Device {
            id,
            processors: vec![
                Processor {
                    kind: ProcKind::Cpu,
                    name: "Mongoose-M4",
                    vf: Processor::vf_table(21, 0.8, 2.7, 0.7, 5.6),
                    idle_power_w: 0.12,
                    peak_gmacs: 42.0,
                    mem_bw_gbs: 15.0,
                    precisions: vec![Precision::Fp32, Precision::Int8],
                    dispatch_overhead_us: 15.0,
                },
                Processor {
                    kind: ProcKind::Gpu,
                    name: "Mali-G76",
                    vf: Processor::vf_table(9, 0.25, 0.7, 0.5, 2.4),
                    idle_power_w: 0.08,
                    peak_gmacs: 95.0,
                    mem_bw_gbs: 15.0,
                    precisions: vec![Precision::Fp32, Precision::Fp16],
                    dispatch_overhead_us: 130.0,
                },
            ],
            dram_gb: 6.0,
            is_mobile: true,
        },
        // Motorola Moto X Force (mid-end): Cortex-A57 1.9 GHz / 15 steps /
        // 3.6 W, Adreno 430 0.6 GHz / 6 steps / 2.0 W.
        DeviceId::MotoXForce => Device {
            id,
            processors: vec![
                Processor {
                    kind: ProcKind::Cpu,
                    name: "Cortex-A57",
                    vf: Processor::vf_table(15, 0.6, 1.9, 0.5, 3.6),
                    idle_power_w: 0.15,
                    peak_gmacs: 10.0,
                    mem_bw_gbs: 7.0,
                    precisions: vec![Precision::Fp32, Precision::Int8],
                    dispatch_overhead_us: 25.0,
                },
                Processor {
                    kind: ProcKind::Gpu,
                    name: "Adreno-430",
                    vf: Processor::vf_table(6, 0.2, 0.6, 0.5, 2.0),
                    idle_power_w: 0.10,
                    peak_gmacs: 28.0,
                    mem_bw_gbs: 7.0,
                    precisions: vec![Precision::Fp32, Precision::Fp16],
                    dispatch_overhead_us: 180.0,
                },
            ],
            dram_gb: 3.0,
            is_mobile: true,
        },
        // Galaxy Tab S6 (connected edge): Cortex-A76 2.84 GHz, Adreno 640,
        // Hexagon 690 — a notch above the phones.
        DeviceId::TabS6 => Device {
            id,
            processors: vec![
                Processor {
                    kind: ProcKind::Cpu,
                    name: "Cortex-A76",
                    vf: Processor::vf_table(20, 0.8, 2.84, 0.8, 6.0),
                    idle_power_w: 0.12,
                    peak_gmacs: 55.0,
                    mem_bw_gbs: 17.0,
                    precisions: vec![Precision::Fp32, Precision::Int8],
                    dispatch_overhead_us: 12.0,
                },
                Processor {
                    kind: ProcKind::Gpu,
                    name: "Adreno-640",
                    vf: Processor::vf_table(8, 0.25, 0.75, 0.7, 3.0),
                    idle_power_w: 0.08,
                    peak_gmacs: 170.0,
                    mem_bw_gbs: 17.0,
                    precisions: vec![Precision::Fp32, Precision::Fp16],
                    dispatch_overhead_us: 110.0,
                },
                Processor {
                    kind: ProcKind::Dsp,
                    name: "Hexagon-690",
                    vf: Processor::vf_table(1, 1.0, 1.0, 2.0, 2.0),
                    idle_power_w: 0.05,
                    peak_gmacs: 240.0,
                    mem_bw_gbs: 14.0,
                    precisions: vec![Precision::Int8],
                    dispatch_overhead_us: 180.0,
                },
            ],
            dram_gb: 8.0,
            is_mobile: true,
        },
        // Cloud: Xeon E5-2640 (40 cores) + NVIDIA P100. Wall power is the
        // server's, but the *device* energy the paper optimizes is the
        // phone's — the server side only contributes latency; its power
        // numbers matter for the latency model, not the phone battery.
        DeviceId::CloudServer => Device {
            id,
            processors: vec![
                Processor {
                    kind: ProcKind::Cpu,
                    name: "Xeon-E5-2640",
                    vf: Processor::vf_table(1, 2.4, 2.4, 90.0, 90.0),
                    idle_power_w: 40.0,
                    peak_gmacs: 600.0,
                    mem_bw_gbs: 60.0,
                    precisions: vec![Precision::Fp32, Precision::Int8],
                    dispatch_overhead_us: 5.0,
                },
                Processor {
                    kind: ProcKind::Gpu,
                    name: "Tesla-P100",
                    vf: Processor::vf_table(1, 1.3, 1.3, 250.0, 250.0),
                    idle_power_w: 30.0,
                    peak_gmacs: 4700.0,
                    mem_bw_gbs: 700.0,
                    precisions: vec![Precision::Fp32, Precision::Fp16],
                    dispatch_overhead_us: 30.0,
                },
            ],
            dram_gb: 256.0,
            is_mobile: false,
        },
    }
}

/// The whole testbed fleet.
pub fn fleet() -> Vec<Device> {
    vec![
        device(DeviceId::Mi8Pro),
        device(DeviceId::GalaxyS10e),
        device(DeviceId::MotoXForce),
        device(DeviceId::TabS6),
        device(DeviceId::CloudServer),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_step_counts_and_peak_power() {
        let mi8 = device(DeviceId::Mi8Pro);
        let cpu = mi8.proc(ProcKind::Cpu).unwrap();
        assert_eq!(cpu.vf.len(), 23);
        assert!((cpu.vf[0].freq_ghz - 2.8).abs() < 1e-9);
        assert!((cpu.vf[0].busy_power_w - 5.5).abs() < 1e-9);
        let gpu = mi8.proc(ProcKind::Gpu).unwrap();
        assert_eq!(gpu.vf.len(), 7);
        assert!((gpu.vf[0].busy_power_w - 2.8).abs() < 1e-9);
        assert!(mi8.has(ProcKind::Dsp));

        let s10 = device(DeviceId::GalaxyS10e);
        assert_eq!(s10.proc(ProcKind::Cpu).unwrap().vf.len(), 21);
        assert!(!s10.has(ProcKind::Dsp), "S10e has no DSP in the paper");

        let moto = device(DeviceId::MotoXForce);
        assert_eq!(moto.proc(ProcKind::Cpu).unwrap().vf.len(), 15);
        assert!((moto.proc(ProcKind::Cpu).unwrap().vf[0].freq_ghz - 1.9).abs() < 1e-9);
    }

    #[test]
    fn performance_ordering_high_vs_mid_end() {
        let mi8 = device(DeviceId::Mi8Pro);
        let moto = device(DeviceId::MotoXForce);
        assert!(
            mi8.proc(ProcKind::Cpu).unwrap().peak_gmacs
                > 3.0 * moto.proc(ProcKind::Cpu).unwrap().peak_gmacs
        );
        let tab = device(DeviceId::TabS6);
        assert!(tab.proc(ProcKind::Cpu).unwrap().peak_gmacs
            > mi8.proc(ProcKind::Cpu).unwrap().peak_gmacs);
        let cloud = device(DeviceId::CloudServer);
        assert!(cloud.proc(ProcKind::Gpu).unwrap().peak_gmacs
            > 20.0 * tab.proc(ProcKind::Gpu).unwrap().peak_gmacs);
    }

    #[test]
    fn dsp_is_int8_only_without_dvfs() {
        let dsp = device(DeviceId::Mi8Pro).proc(ProcKind::Dsp).unwrap().clone();
        assert_eq!(dsp.precisions, vec![Precision::Int8]);
        assert_eq!(dsp.vf.len(), 1);
    }

    #[test]
    fn fleet_has_five_devices() {
        assert_eq!(fleet().len(), 5);
    }

    #[test]
    fn coprocessor_dispatch_costlier_than_cpu() {
        // The Fig. 3 mechanism: co-processors pay per-layer dispatch.
        for d in fleet() {
            let cpu_ovh = d.proc(ProcKind::Cpu).unwrap().dispatch_overhead_us;
            for p in &d.processors {
                if p.kind != ProcKind::Cpu {
                    assert!(p.dispatch_overhead_us > cpu_ovh, "{:?}/{:?}", d.id, p.kind);
                }
            }
        }
    }
}
