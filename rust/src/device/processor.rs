//! Parametric processor + device models.
//!
//! Each processor carries a DVFS V/F table (frequency + busy power per
//! step, paper Table 2 gives step counts and peak power), an idle power, a
//! peak MAC rate and memory bandwidth, and the set of precisions its
//! deployed executables support (§5.3: CPU fp32+int8, GPU fp32+fp16,
//! DSP int8).

use crate::types::{DeviceId, Precision, ProcKind};

/// One DVFS operating point.
#[derive(Clone, Copy, Debug)]
pub struct VfStep {
    /// Clock frequency in GHz.
    pub freq_ghz: f64,
    /// Power while busy at this step (watts).
    pub busy_power_w: f64,
}

/// A processor on a device.
#[derive(Clone, Debug)]
pub struct Processor {
    pub kind: ProcKind,
    pub name: &'static str,
    /// V/F table sorted max-frequency-first (index 0 = fastest).
    pub vf: Vec<VfStep>,
    /// Idle power at the cluster level (watts).
    pub idle_power_w: f64,
    /// Peak fp32 multiply-accumulate rate at max frequency (GMAC/s).
    pub peak_gmacs: f64,
    /// Sustainable memory bandwidth (GB/s).
    pub mem_bw_gbs: f64,
    /// Precisions the deployment stack supports on this processor.
    pub precisions: Vec<Precision>,
    /// Fixed per-kernel dispatch overhead (µs) — the co-processor launch
    /// cost that makes many-small-FC networks CPU-favoured (Fig. 3).
    pub dispatch_overhead_us: f64,
}

impl Processor {
    /// Build a V/F table by interpolating from (f_min, p_min) to
    /// (f_max, p_max) over `steps` points. Power follows ~f^3 (P = C·V²·f
    /// with V roughly linear in f), matching measured mobile DVFS curves.
    pub fn vf_table(steps: usize, f_min: f64, f_max: f64, p_min: f64, p_max: f64) -> Vec<VfStep> {
        assert!(steps >= 1 && f_max >= f_min);
        (0..steps)
            .map(|i| {
                // index 0 = max frequency
                let t = if steps == 1 { 1.0 } else { 1.0 - i as f64 / (steps - 1) as f64 };
                let freq = f_min + t * (f_max - f_min);
                let x = if f_max > f_min { (freq - f_min) / (f_max - f_min) } else { 1.0 };
                let power = p_min + (p_max - p_min) * x.powi(3);
                VfStep { freq_ghz: freq, busy_power_w: power }
            })
            .collect()
    }

    pub fn supports(&self, precision: Precision) -> bool {
        self.precisions.contains(&precision)
    }

    /// Clamp a V/F index into the table.
    pub fn step(&self, idx: u8) -> VfStep {
        self.vf[(idx as usize).min(self.vf.len() - 1)]
    }

    /// Frequency ratio of step `idx` relative to max (0 < r <= 1).
    pub fn freq_ratio(&self, idx: u8) -> f64 {
        self.step(idx).freq_ghz / self.vf[0].freq_ghz
    }

    /// Effective MAC throughput (GMAC/s) at a V/F step and precision.
    ///
    /// INT8 roughly doubles effective MACs on CPU (dot-product extensions)
    /// and is the DSP's native mode (already captured in its peak);
    /// FP16 roughly doubles GPU ALU throughput.
    pub fn effective_gmacs(&self, idx: u8, precision: Precision) -> f64 {
        let base = self.peak_gmacs * self.freq_ratio(idx);
        let speedup = match (self.kind, precision) {
            (ProcKind::Cpu, Precision::Int8) => 2.0,
            (ProcKind::Gpu, Precision::Fp16) => 2.0,
            (ProcKind::Dsp, Precision::Int8) => 1.0, // int8 is the DSP baseline
            _ => 1.0,
        };
        base * speedup
    }
}

/// A device: a set of processors plus global traits.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: DeviceId,
    pub processors: Vec<Processor>,
    pub dram_gb: f64,
    /// Is this a battery-powered edge device (thermal limits apply)?
    pub is_mobile: bool,
}

impl Device {
    pub fn proc(&self, kind: ProcKind) -> Option<&Processor> {
        self.processors.iter().find(|p| p.kind == kind)
    }

    pub fn has(&self, kind: ProcKind) -> bool {
        self.proc(kind).is_some()
    }

    /// All (proc, vf, precision) actions this device can execute locally.
    pub fn local_actions(&self) -> Vec<(ProcKind, u8, Precision)> {
        let mut out = Vec::new();
        for p in &self.processors {
            let vf_count = if p.kind == ProcKind::Dsp {
                1 // §5.3: no DVFS on the DSP
            } else {
                p.vf.len()
            };
            for vf in 0..vf_count {
                for &prec in &p.precisions {
                    out.push((p.kind, vf as u8, prec));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cpu() -> Processor {
        Processor {
            kind: ProcKind::Cpu,
            name: "test-cpu",
            vf: Processor::vf_table(5, 0.8, 2.8, 0.8, 5.5),
            idle_power_w: 0.1,
            peak_gmacs: 20.0,
            mem_bw_gbs: 10.0,
            precisions: vec![Precision::Fp32, Precision::Int8],
            dispatch_overhead_us: 20.0,
        }
    }

    #[test]
    fn vf_table_max_first_monotone() {
        let t = Processor::vf_table(7, 1.0, 2.0, 1.0, 4.0);
        assert_eq!(t.len(), 7);
        assert!((t[0].freq_ghz - 2.0).abs() < 1e-12);
        assert!((t[6].freq_ghz - 1.0).abs() < 1e-12);
        for w in t.windows(2) {
            assert!(w[0].freq_ghz >= w[1].freq_ghz);
            assert!(w[0].busy_power_w >= w[1].busy_power_w);
        }
        // cubic power curve: max power at max freq, min power at min freq
        assert!((t[0].busy_power_w - 4.0).abs() < 1e-9);
        assert!((t[6].busy_power_w - 1.0).abs() < 1e-9);
    }

    #[test]
    fn step_clamps() {
        let p = cpu();
        assert!((p.step(200).freq_ghz - 0.8).abs() < 1e-12);
        assert!((p.freq_ratio(0) - 1.0).abs() < 1e-12);
        assert!(p.freq_ratio(4) < 0.3 + 1e-9);
    }

    #[test]
    fn int8_speeds_up_cpu() {
        let p = cpu();
        assert!(
            p.effective_gmacs(0, Precision::Int8) > p.effective_gmacs(0, Precision::Fp32)
        );
    }

    #[test]
    fn local_actions_cover_precisions_and_steps() {
        let d = Device {
            id: DeviceId::Mi8Pro,
            processors: vec![cpu()],
            dram_gb: 6.0,
            is_mobile: true,
        };
        let acts = d.local_actions();
        // 5 V/F steps x 2 precisions
        assert_eq!(acts.len(), 10);
        assert!(acts.iter().all(|(k, _, _)| *k == ProcKind::Cpu));
    }
}
