//! Device fleet simulator — the paper's testbed hardware (Table 2 + §5.1)
//! as parametric processor models: per-processor V/F tables with busy/idle
//! power, peak compute/bandwidth, precision support and a thermal-throttling
//! state machine.

pub mod presets;
pub mod processor;
pub mod thermal;

pub use presets::{device, fleet};
pub use processor::{Device, Processor, VfStep};
pub use thermal::ThermalState;
