//! PJRT runtime: loads the AOT HLO artifacts and executes them on the CPU
//! PJRT client from the request path. Python is never involved here.
//!
//! Flow (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! (text interchange — jax>=0.5 serialized protos are rejected by the
//! bundled xla_extension 0.5.1) → `XlaComputation::from_proto` →
//! `client.compile` → `executable.execute`.
//!
//! Compiled executables are cached per artifact so each (model, precision)
//! pays XLA compilation exactly once per process; the hot path is execute()
//! plus one literal→buffer upload.
//!
//! Only built with the `pjrt` cargo feature (requires the `xla` bindings,
//! absent from the offline crate cache); the default build uses the
//! deterministic `sim` engine behind the same API.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::nn::manifest::{ArtifactEntry, Manifest};
use crate::types::Precision;
use crate::util::rng::Pcg64;

use super::ExecTiming;

/// The PJRT engine: client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<(String, Precision), xla::PjRtLoadedExecutable>,
    /// Calibration mean wall time per artifact (seconds), filled lazily by
    /// the shared `calibrate`/`compute_factor` impl in `runtime::mod`.
    pub(super) calibration: HashMap<(String, Precision), f64>,
}

impl Engine {
    /// Create a CPU PJRT engine over the given artifact manifest.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: HashMap::new(), calibration: HashMap::new() })
    }

    /// Convenience: load the default manifest location.
    pub fn from_default_manifest() -> Result<Engine> {
        Engine::new(Manifest::load_default()?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) executable for a (model, precision).
    pub fn load(&mut self, model: &str, precision: Precision) -> Result<()> {
        let key = (model.to_string(), precision);
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let entry = self
            .manifest
            .find(model, precision)
            .with_context(|| format!("artifact {model}/{precision} not in manifest"))?
            .clone();
        let exe = self.compile_artifact(&entry)?;
        self.cache.insert(key, exe);
        Ok(())
    }

    fn compile_artifact(&self, entry: &ArtifactEntry) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            entry
                .artifact
                .to_str()
                .context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", entry.artifact))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA compile of {:?}", entry.artifact))
    }

    /// Execute one inference with a deterministic pseudo-random input drawn
    /// from `seed` (the models embed their weights; input is the image /
    /// token embedding tensor).
    pub fn execute(&mut self, model: &str, precision: Precision, seed: u64) -> Result<ExecTiming> {
        self.load(model, precision)?;
        let entry = self.manifest.find(model, precision).unwrap().clone();
        let exe = self.cache.get(&(model.to_string(), precision)).unwrap();

        let n: usize = entry.input_shape.iter().product();
        let mut rng = Pcg64::new(seed);
        let data: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let dims: Vec<i64> = entry.input_shape.iter().map(|&d| d as i64).collect();

        let t0 = Instant::now();
        let input = xla::Literal::vec1(&data)
            .reshape(&dims)
            .context("reshaping input literal")?;
        let result = exe
            .execute::<xla::Literal>(&[input])
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let wall_s = t0.elapsed().as_secs_f64();
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let output = out.to_vec::<f32>().unwrap_or_default();
        Ok(ExecTiming { wall_s, output })
    }

    /// Number of compiled executables resident.
    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}
