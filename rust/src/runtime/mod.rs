//! Execution runtime for the AOT HLO artifacts.
//!
//! Two interchangeable engines sit behind one API:
//!
//! * **`pjrt`** (cargo feature `pjrt`) — the real thing: loads the
//!   `artifacts/*.hlo.txt` files produced by `aot.py` and executes them on
//!   the CPU PJRT client through the `xla` bindings. Python is never
//!   involved on the request path. Enabling the feature requires the `xla`
//!   crate, which the offline build environment does not ship.
//! * **`sim`** (default) — an API-identical deterministic stand-in: it
//!   validates artifacts against the same manifest, models per-artifact
//!   wall time from the manifest's tiny-scale MAC counts with seeded
//!   run-to-run jitter, and produces seed-deterministic pseudo-outputs.
//!   Everything downstream (serving loop grounding via `compute_factor`,
//!   failure-injection behaviour on missing artifacts, calibration) works
//!   identically, so the coordinator and tests exercise the same code
//!   paths in both builds.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod sim;

#[cfg(feature = "pjrt")]
pub use pjrt::Engine;
#[cfg(not(feature = "pjrt"))]
pub use sim::Engine;

/// A timed execution result.
#[derive(Clone, Debug)]
pub struct ExecTiming {
    /// Wall-clock seconds for buffer upload + execute + fetch.
    pub wall_s: f64,
    /// Flattened output values (first tuple element).
    pub output: Vec<f32>,
}

/// Calibration-based compute grounding, shared by both engines — they
/// differ only in how `execute` produces wall time.
impl Engine {
    /// Mean wall time over `n` runs — the calibration anchor for the
    /// compute_factor fed into the simulator.
    pub fn calibrate(
        &mut self,
        model: &str,
        precision: crate::types::Precision,
        n: usize,
    ) -> anyhow::Result<f64> {
        let mut total = 0.0;
        for i in 0..n.max(1) {
            total += self.execute(model, precision, 1000 + i as u64)?.wall_s;
        }
        let mean = total / n.max(1) as f64;
        self.calibration.insert((model.to_string(), precision), mean);
        Ok(mean)
    }

    /// Real-compute factor for one fresh execution: wall / calibration
    /// mean; 1.0 when uncalibrated. This is how measured execution
    /// variance perturbs the simulated latency.
    pub fn compute_factor(
        &mut self,
        model: &str,
        precision: crate::types::Precision,
        seed: u64,
    ) -> anyhow::Result<f64> {
        let key = (model.to_string(), precision);
        let cal = match self.calibration.get(&key) {
            Some(&c) => c,
            None => self.calibrate(model, precision, 3)?,
        };
        let wall = self.execute(model, precision, seed)?.wall_s;
        Ok((wall / cal.max(1e-9)).clamp(0.25, 4.0))
    }
}

#[cfg(test)]
mod tests {
    //! These tests need `artifacts/` built (`make artifacts`); they are the
    //! integration proof that the AOT bridge works end to end. They run
    //! against whichever engine the build selected.
    use super::*;
    use crate::nn::manifest::Manifest;
    use crate::types::Precision;

    fn engine() -> Option<Engine> {
        match Manifest::load_default() {
            Ok(m) => Engine::new(m).ok(),
            Err(_) => None, // artifacts not built; skip
        }
    }

    #[test]
    fn executes_light_model_and_caches() {
        let Some(mut e) = engine() else { return };
        let t1 = e.execute("mobilenet_v1", Precision::Fp32, 1).unwrap();
        assert!(!t1.output.is_empty());
        assert!(t1.output.iter().all(|v| v.is_finite()));
        assert_eq!(e.loaded_count(), 1);
        let t2 = e.execute("mobilenet_v1", Precision::Fp32, 2).unwrap();
        assert_eq!(t1.output.len(), t2.output.len());
        assert_eq!(e.loaded_count(), 1, "compile exactly once");
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(mut e) = engine() else { return };
        let a = e.execute("mobilenet_v1", Precision::Fp32, 7).unwrap();
        let b = e.execute("mobilenet_v1", Precision::Fp32, 7).unwrap();
        assert_eq!(a.output, b.output);
    }

    #[test]
    fn int8_artifact_executes() {
        let Some(mut e) = engine() else { return };
        let t = e.execute("mobilenet_v1", Precision::Int8, 3).unwrap();
        assert!(t.output.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn compute_factor_near_one() {
        let Some(mut e) = engine() else { return };
        let f = e.compute_factor("mobilenet_v1", Precision::Fp32, 11).unwrap();
        assert!((0.25..=4.0).contains(&f), "factor {f}");
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(mut e) = engine() else { return };
        assert!(e.execute("nonexistent_model", Precision::Fp32, 0).is_err());
    }
}
