//! Deterministic stand-in for the PJRT engine (default build).
//!
//! Mirrors `pjrt::Engine`'s API and observable behaviour exactly:
//! artifacts are resolved through the same manifest and must exist on
//! disk (so failure-injection paths behave identically), outputs are a
//! pure function of the request seed, and per-execution wall time is
//! modelled from the artifact's tiny-scale MAC count with seeded
//! run-to-run jitter — which is what `compute_factor` feeds back into the
//! latency simulator as "real" compute variance.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::nn::manifest::{ArtifactEntry, Manifest};
use crate::types::Precision;
use crate::util::rng::Pcg64;

use super::ExecTiming;

/// The simulated engine: manifest + "loaded" artifact cache.
pub struct Engine {
    manifest: Manifest,
    cache: HashMap<(String, Precision), ArtifactEntry>,
    /// Calibration mean wall time per artifact (seconds), filled lazily by
    /// the shared `calibrate`/`compute_factor` impl in `runtime::mod`.
    pub(super) calibration: HashMap<(String, Precision), f64>,
}

/// Stable per-artifact RNG stream id so different (model, precision)
/// pairs draw independent jitter for the same request seed.
fn stream_id(model: &str, precision: Precision) -> u64 {
    crate::util::hash::fnv1a_bytes(model.as_bytes())
        ^ match precision {
            Precision::Fp32 => 1,
            Precision::Fp16 => 2,
            Precision::Int8 => 3,
        }
}

impl Engine {
    /// Create a simulated engine over the given artifact manifest.
    pub fn new(manifest: Manifest) -> Result<Engine> {
        Ok(Engine { manifest, cache: HashMap::new(), calibration: HashMap::new() })
    }

    /// Convenience: load the default manifest location.
    pub fn from_default_manifest() -> Result<Engine> {
        Engine::new(Manifest::load_default()?)
    }

    pub fn platform(&self) -> String {
        "sim-cpu (build without `pjrt` feature)".to_string()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Validate (or fetch cached) the artifact for a (model, precision).
    /// Like the real engine's compile step, this fails when the manifest
    /// has no entry or the artifact file is missing on disk.
    pub fn load(&mut self, model: &str, precision: Precision) -> Result<()> {
        let key = (model.to_string(), precision);
        if self.cache.contains_key(&key) {
            return Ok(());
        }
        let entry = self
            .manifest
            .find(model, precision)
            .with_context(|| format!("artifact {model}/{precision} not in manifest"))?
            .clone();
        anyhow::ensure!(
            entry.artifact.exists(),
            "artifact file missing: {:?} (run `make artifacts`)",
            entry.artifact
        );
        self.cache.insert(key, entry);
        Ok(())
    }

    /// Execute one inference with a deterministic pseudo-random input drawn
    /// from `seed`. Output and wall time are pure functions of
    /// (model, precision, seed).
    pub fn execute(&mut self, model: &str, precision: Precision, seed: u64) -> Result<ExecTiming> {
        self.load(model, precision)?;
        let entry = self.cache.get(&(model.to_string(), precision)).unwrap();
        let n: usize = entry.input_shape.iter().product::<usize>().max(1);
        let mut rng = Pcg64::with_stream(seed, stream_id(model, precision));
        // Base wall time from the artifact's own (tiny-scale) compute,
        // plus bounded multiplicative run-to-run jitter.
        let base_s = 2e-5 + entry.macs as f64 * 1e-9;
        let wall_s = base_s * (1.0 + rng.normal(0.0, 0.08)).clamp(0.7, 1.5);
        let output: Vec<f32> = (0..n.min(1024)).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        Ok(ExecTiming { wall_s, output })
    }

    /// Number of validated artifacts resident.
    pub fn loaded_count(&self) -> usize {
        self.cache.len()
    }
}
