//! The estimator-driven autoscaler: pooled utilization and queue-wait
//! estimates feed a threshold/cooldown [`ScalingRule`], evaluated once
//! per epoch on the main thread. The output is a *target replica count*
//! moving by at most one replica per evaluation — smooth, bounded, and a
//! pure function of the epoch-aggregate sequence (so the trajectory is
//! shard-invariant and seed-reproducible by construction).

use super::estimator::Estimator;

/// Threshold/cooldown policy deciding when the pool grows or shrinks.
#[derive(Clone, Copy, Debug)]
pub struct ScalingRule {
    /// Scale up when the utilization estimate exceeds this.
    pub up_utilization: f64,
    /// Scale down when the utilization estimate falls below this.
    pub down_utilization: f64,
    /// Scale up (regardless of utilization) when the queue-wait estimate
    /// exceeds this many seconds — the backlog escape hatch.
    pub up_queue_wait_s: f64,
    /// Minimum seconds between consecutive scale-ups.
    pub up_cooldown_s: f64,
    /// Minimum seconds between consecutive scale-downs (longer than up,
    /// so the pool is quick to grow and reluctant to shrink).
    pub down_cooldown_s: f64,
}

impl Default for ScalingRule {
    fn default() -> Self {
        ScalingRule {
            up_utilization: 0.75,
            down_utilization: 0.30,
            up_queue_wait_s: 1.0,
            up_cooldown_s: 10.0,
            down_cooldown_s: 30.0,
        }
    }
}

/// Autoscaler configuration: replica bounds, the rule, and the warm-up
/// lag a fresh replica sits out before serving. Neutral default:
/// `min == max == 1` pins the pool to one replica — the autoscaler then
/// never changes anything and the elastic cloud is bit-identical to the
/// fixed one.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalerParams {
    pub min_replicas: usize,
    pub max_replicas: usize,
    pub rule: ScalingRule,
    /// Seconds between a scale-up decision and the new replica serving
    /// its first request.
    pub warmup_s: f64,
}

impl Default for AutoscalerParams {
    fn default() -> Self {
        AutoscalerParams {
            min_replicas: 1,
            max_replicas: 1,
            rule: ScalingRule::default(),
            warmup_s: 20.0,
        }
    }
}

/// Estimator variances: utilization is a fairly clean per-epoch ratio,
/// queue wait is spikier — smooth it harder.
const UTIL_PROCESS_VAR: f64 = 0.05;
const UTIL_MEASURE_VAR: f64 = 0.25;
const WAIT_PROCESS_VAR: f64 = 0.05;
const WAIT_MEASURE_VAR: f64 = 1.0;

/// The live autoscaler: two estimators plus per-direction cooldown
/// clocks. `evaluate` is the only entry point and must be called exactly
/// once per epoch, on the main thread, with the pooled aggregates.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    params: AutoscalerParams,
    util: Estimator,
    wait: Estimator,
    last_up_s: f64,
    last_down_s: f64,
}

impl Autoscaler {
    pub fn new(params: AutoscalerParams) -> Self {
        Autoscaler {
            params,
            util: Estimator::new(UTIL_PROCESS_VAR, UTIL_MEASURE_VAR),
            wait: Estimator::new(WAIT_PROCESS_VAR, WAIT_MEASURE_VAR),
            last_up_s: f64::NEG_INFINITY,
            last_down_s: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn params(&self) -> &AutoscalerParams {
        &self.params
    }

    /// Smoothed utilization estimate (for telemetry / experiments).
    #[inline]
    pub fn utilization_estimate(&self) -> f64 {
        self.util.value()
    }

    /// Fold this epoch's pooled utilization and queue wait, then return
    /// the target replica count given `current` provisioned replicas.
    /// Moves by at most one replica per call; respects bounds and
    /// per-direction cooldowns.
    pub fn evaluate(&mut self, t_s: f64, utilization: f64, queue_wait_s: f64, current: usize) -> usize {
        let u = self.util.update(utilization);
        let w = self.wait.update(queue_wait_s);
        let p = self.params;
        // Bounds first: a reconfigured pool snaps toward the band one
        // step at a time even when no threshold fires.
        if current < p.min_replicas {
            return current + 1;
        }
        if current > p.max_replicas {
            return current - 1;
        }
        let want_up = u > p.rule.up_utilization || w > p.rule.up_queue_wait_s;
        if want_up && current < p.max_replicas && t_s - self.last_up_s >= p.rule.up_cooldown_s {
            self.last_up_s = t_s;
            return current + 1;
        }
        let want_down = u < p.rule.down_utilization && w < p.rule.up_queue_wait_s;
        if want_down && current > p.min_replicas && t_s - self.last_down_s >= p.rule.down_cooldown_s
        {
            self.last_down_s = t_s;
            return current - 1;
        }
        current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn elastic_params(max: usize) -> AutoscalerParams {
        AutoscalerParams { min_replicas: 1, max_replicas: max, ..Default::default() }
    }

    /// Drive the autoscaler with a constant signal and return the
    /// replica-count trajectory, one entry per epoch.
    fn trajectory(p: AutoscalerParams, util: f64, wait: f64, epochs: usize) -> Vec<usize> {
        let mut a = Autoscaler::new(p);
        let mut n = p.min_replicas;
        let mut out = Vec::with_capacity(epochs);
        for e in 0..epochs {
            n = a.evaluate(e as f64, util, wait, n);
            out.push(n);
        }
        out
    }

    #[test]
    fn sustained_overload_scales_monotonically_up_to_max() {
        let traj = trajectory(elastic_params(4), 0.95, 0.0, 120);
        assert!(traj.windows(2).all(|w| w[1] >= w[0]), "monotone under overload: {traj:?}");
        assert_eq!(*traj.last().unwrap(), 4, "reaches max_replicas");
        assert!(traj.iter().all(|&n| (1..=4).contains(&n)));
    }

    #[test]
    fn sustained_underload_scales_monotonically_down_to_min() {
        let p = elastic_params(4);
        let mut a = Autoscaler::new(p);
        let mut n = 4;
        let mut traj = Vec::new();
        for e in 0..300 {
            n = a.evaluate(e as f64, 0.05, 0.0, n);
            traj.push(n);
        }
        assert!(traj.windows(2).all(|w| w[1] <= w[0]), "monotone under underload: {traj:?}");
        assert_eq!(*traj.last().unwrap(), 1, "reaches min_replicas");
    }

    #[test]
    fn cooldown_spaces_consecutive_scale_ups() {
        let p = elastic_params(8);
        let traj = trajectory(p, 0.95, 0.0, 60);
        // Find epochs where the count grew; consecutive growth events
        // must be at least up_cooldown_s apart (epochs are 1 s here).
        let ups: Vec<usize> =
            traj.windows(2).enumerate().filter(|(_, w)| w[1] > w[0]).map(|(i, _)| i + 1).collect();
        assert!(ups.len() >= 2, "need multiple scale-ups to test spacing: {traj:?}");
        for pair in ups.windows(2) {
            assert!(
                (pair[1] - pair[0]) as f64 >= p.rule.up_cooldown_s,
                "scale-ups at {ups:?} violate the {}s cooldown",
                p.rule.up_cooldown_s
            );
        }
    }

    #[test]
    fn bounds_are_never_violated_under_any_signal() {
        let p = elastic_params(3);
        let mut a = Autoscaler::new(p);
        let mut n = 1;
        // Adversarial alternating signal: saturated then idle.
        for e in 0..500 {
            let (u, w) = if e % 3 == 0 { (5.0, 30.0) } else { (0.0, 0.0) };
            n = a.evaluate(e as f64, u, w, n);
            assert!((1..=3).contains(&n), "bounds violated at epoch {e}: {n}");
        }
    }

    #[test]
    fn queue_wait_alone_triggers_scale_up() {
        // Utilization below the up threshold, but the queue is deep:
        // the wait estimator must force growth.
        let traj = trajectory(elastic_params(2), 0.5, 10.0, 60);
        assert_eq!(*traj.last().unwrap(), 2);
    }

    #[test]
    fn pinned_bounds_pin_the_count() {
        let traj = trajectory(AutoscalerParams::default(), 5.0, 100.0, 50);
        assert!(traj.iter().all(|&n| n == 1), "min=max=1 must never move: {traj:?}");
    }

    #[test]
    fn out_of_band_counts_snap_back_one_step_at_a_time() {
        let p = elastic_params(2);
        let mut a = Autoscaler::new(p);
        assert_eq!(a.evaluate(0.0, 0.0, 0.0, 5), 4, "above max: shrink");
        let mut a = Autoscaler::new(AutoscalerParams { min_replicas: 3, max_replicas: 4, ..Default::default() });
        assert_eq!(a.evaluate(0.0, 0.0, 0.0, 1), 2, "below min: grow");
    }

    #[test]
    fn trajectory_is_reproducible() {
        let t1 = trajectory(elastic_params(6), 0.9, 2.0, 200);
        let t2 = trajectory(elastic_params(6), 0.9, 2.0, 200);
        assert_eq!(t1, t2);
    }
}
