//! The replica pool: N homogeneous [`CloudModel`] replicas behind
//! deterministic dispatch, folded into one pooled [`CloudSnapshot`] per
//! epoch. The pool is the drop-in replacement for the single fixed
//! cloud in `fleet/sim.rs` — with the neutral [`ElasticParams`] defaults
//! it holds exactly one replica forever and every arithmetic step
//! reduces to the single-model path bit-for-bit (pinned below and by
//! the driver-parity test in `tests/fleet.rs`).
//!
//! Epoch-boundary order of operations (all on the main thread):
//! 1. split the epoch's offload aggregate across the replicas that were
//!    ready when the epoch started, advance each replica's queue;
//! 2. fold pooled utilization / queue wait, feed the [`Autoscaler`];
//! 3. apply the batch schedule (window changes refresh every replica's
//!    frozen `batch_wait_s` — see `CloudModel::set_batch_window`);
//! 4. apply the scaling decision: grow by one warming replica, or
//!    retire the tail replica and redistribute its backlog evenly;
//! 5. freeze the pooled view ([`PoolView`]) the next epoch runs
//!    against: snapshot, admission decision, replica count.
//!
//! A retired replica's backlog is absorbed by the survivors immediately
//! but shows up in their snapshots only after their next advance — a
//! one-epoch reporting lag the fluid approximation tolerates by design.

use super::autoscaler::Autoscaler;
use super::{BatchSchedule, DispatchKind, ElasticParams, PoolView, Replica};
use crate::fleet::{CloudModel, CloudParams, CloudSnapshot};

/// The elastic cloud: replicas + autoscaler + admission state.
#[derive(Clone, Debug)]
pub struct ReplicaPool {
    base: CloudParams,
    elastic: ElasticParams,
    replicas: Vec<Replica>,
    autoscaler: Autoscaler,
    /// Round-robin remainder cursor, persisted across epochs.
    rr_cursor: usize,
    /// Simulation clock: start time of the next epoch to fold.
    t_s: f64,
    view: PoolView,
}

impl ReplicaPool {
    /// Build a pool with `min_replicas` pre-provisioned (ready) replicas.
    pub fn new(base: CloudParams, elastic: ElasticParams) -> Self {
        let n0 = elastic.autoscaler.min_replicas.max(1);
        let replicas: Vec<Replica> = (0..n0)
            .map(|_| Replica { model: CloudModel::new(base), ready_at_s: 0.0 })
            .collect();
        let autoscaler = Autoscaler::new(elastic.autoscaler);
        let mut pool = ReplicaPool {
            base,
            elastic,
            replicas,
            autoscaler,
            rr_cursor: 0,
            t_s: 0.0,
            view: PoolView {
                snapshot: CloudSnapshot {
                    queue_wait_s: 0.0,
                    batch_wait_s: 0.5 * base.batch_window_s,
                    load: 0.0,
                    slowdown: 1.0,
                },
                admitting: true,
                replicas: n0 as u32,
            },
        };
        pool.refresh_view();
        pool
    }

    /// The frozen view the coming epoch runs against.
    #[inline]
    pub fn view(&self) -> PoolView {
        self.view
    }

    /// Pooled congestion snapshot (the same shape devices always read).
    #[inline]
    pub fn snapshot(&self) -> CloudSnapshot {
        self.view.snapshot
    }

    /// False = every offload this epoch fast-fails at admission.
    #[inline]
    pub fn admitting(&self) -> bool {
        self.view.admitting
    }

    /// Provisioned replicas, warming ones included.
    #[inline]
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Replicas ready to serve at the current epoch boundary.
    pub fn n_active(&self) -> usize {
        self.replicas.iter().filter(|r| r.ready_at_s <= self.t_s).count()
    }

    /// Total pending work across every replica (deterministic id-order
    /// sum; with one replica this is exactly that replica's backlog).
    pub fn backlog_mmacs(&self) -> f64 {
        self.replicas.iter().map(|r| r.model.backlog_mmacs()).sum()
    }

    /// Smoothed utilization estimate the autoscaler is acting on.
    #[inline]
    pub fn utilization_estimate(&self) -> f64 {
        self.autoscaler.utilization_estimate()
    }

    /// Indices of replicas ready at epoch start `t`.
    fn active_indices(&self, t: f64) -> Vec<usize> {
        (0..self.replicas.len()).filter(|&i| self.replicas[i].ready_at_s <= t).collect()
    }

    /// Fold one epoch of offered traffic (the deterministically-reduced
    /// fleet aggregate), run the autoscaler, and freeze the next view.
    /// Mirrors `CloudModel::advance_epoch` exactly when one replica is
    /// pinned.
    pub fn advance_epoch(&mut self, jobs: u64, macs_m: f64, epoch_s: f64) {
        assert!(epoch_s > 0.0);
        let t_start = self.t_s;
        let active = self.active_indices(t_start);
        debug_assert!(!active.is_empty(), "pool always keeps a ready replica");
        let k = active.len();

        // 1. dispatch: even integer job split, remainder placed per the
        // dispatch kind; MACs follow the job shares proportionally. With
        // one active replica the whole aggregate passes through exactly.
        let base_jobs = jobs / k as u64;
        let rem = (jobs % k as u64) as usize;
        let mut share = vec![base_jobs; k];
        match self.elastic.dispatch {
            DispatchKind::RoundRobin => {
                for j in 0..rem {
                    share[(self.rr_cursor + j) % k] += 1;
                }
                self.rr_cursor = (self.rr_cursor + rem) % k;
            }
            DispatchKind::LeastBacklog => {
                let mut order: Vec<usize> = (0..k).collect();
                order.sort_by(|&a, &b| {
                    let ba = self.replicas[active[a]].model.backlog_mmacs();
                    let bb = self.replicas[active[b]].model.backlog_mmacs();
                    ba.partial_cmp(&bb).unwrap().then(a.cmp(&b))
                });
                for j in 0..rem {
                    share[order[j]] += 1;
                }
            }
        }
        for (pos, &i) in active.iter().enumerate() {
            let macs_i = if jobs > 0 {
                macs_m * (share[pos] as f64 / jobs as f64)
            } else {
                macs_m / k as f64
            };
            self.replicas[i].model.advance_epoch(share[pos], macs_i, epoch_s);
        }
        // Warming replicas idle through the epoch (their queues stay
        // empty, their snapshots stay fresh for the moment they join).
        for i in 0..self.replicas.len() {
            if self.replicas[i].ready_at_s > t_start {
                self.replicas[i].model.advance_epoch(0, 0.0, epoch_s);
            }
        }
        let t_end = t_start + epoch_s;
        self.t_s = t_end;

        // 2. pooled aggregates over the replicas that served this epoch.
        let kf = k as f64;
        let util: f64 = active.iter().map(|&i| self.replicas[i].model.snapshot().load).sum::<f64>() / kf;
        let wait: f64 =
            active.iter().map(|&i| self.replicas[i].model.snapshot().queue_wait_s).sum::<f64>() / kf;

        // 3. load-dependent batch schedule (Static never touches it).
        if self.elastic.batch != BatchSchedule::Static {
            let w = self.base.batch_window_s * self.elastic.batch.multiplier(util);
            for r in &mut self.replicas {
                r.model.set_batch_window(w);
            }
        }

        // 4. scaling: at most one replica per epoch, warm-up lag on the
        // way up, deterministic tail retirement + even backlog
        // redistribution on the way down.
        let target = self.autoscaler.evaluate(t_end, util, wait, self.replicas.len());
        if target > self.replicas.len() {
            // Inherit the pool's current (possibly widened) window so
            // the pool stays homogeneous.
            let params = self.replicas[0].model.params;
            self.replicas.push(Replica {
                model: CloudModel::new(params),
                ready_at_s: t_end + self.elastic.autoscaler.warmup_s,
            });
        } else if target < self.replicas.len() {
            let mut dead = self.replicas.pop().expect("target >= min >= 1");
            let (macs, jobs) = dead.model.take_backlog();
            let kf = self.replicas.len() as f64;
            for r in &mut self.replicas {
                r.model.absorb_backlog(macs / kf, jobs / kf);
            }
            self.rr_cursor = 0; // active set changed: reset the cursor
        }

        // 5. freeze the view for the coming epoch.
        self.refresh_view();
    }

    /// Recompute the frozen [`PoolView`] from the replicas that will be
    /// ready when the next epoch starts. One active replica passes its
    /// snapshot through verbatim (the bit-exact neutral path); several
    /// average field-wise — the expectation a round-robin-dispatched
    /// request sees.
    fn refresh_view(&mut self) {
        let active = self.active_indices(self.t_s);
        let snapshot = if active.len() == 1 {
            self.replicas[active[0]].model.snapshot()
        } else {
            let kf = active.len() as f64;
            let mut queue_wait_s = 0.0;
            let mut batch_wait_s = 0.0;
            let mut load = 0.0;
            let mut slowdown = 0.0;
            for &i in &active {
                let s = self.replicas[i].model.snapshot();
                queue_wait_s += s.queue_wait_s;
                batch_wait_s += s.batch_wait_s;
                load += s.load;
                slowdown += s.slowdown;
            }
            CloudSnapshot {
                queue_wait_s: queue_wait_s / kf,
                batch_wait_s: batch_wait_s / kf,
                load: load / kf,
                slowdown: slowdown / kf,
            }
        };
        let admitting = snapshot.queue_wait_s <= self.elastic.admit_backlog_s;
        self.view = PoolView { snapshot, admitting, replicas: self.replicas.len() as u32 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloudscale::AutoscalerParams;

    fn overload_epochs() -> Vec<(u64, f64)> {
        let cap = CloudParams::default().capacity_mmacs_per_s;
        (0..40)
            .map(|i| match i % 5 {
                0 => (0, 0.0),
                1 => (500, 0.3 * cap),
                _ => (20_000, 2.5 * cap),
            })
            .collect()
    }

    #[test]
    fn neutral_pool_is_bit_identical_to_a_single_cloud_model() {
        let params = CloudParams::default();
        let mut pool = ReplicaPool::new(params, ElasticParams::default());
        let mut single = CloudModel::new(params);
        for &(jobs, macs) in &overload_epochs() {
            pool.advance_epoch(jobs, macs, 1.0);
            single.advance_epoch(jobs, macs, 1.0);
            let (p, s) = (pool.snapshot(), single.snapshot());
            assert_eq!(p.queue_wait_s.to_bits(), s.queue_wait_s.to_bits());
            assert_eq!(p.batch_wait_s.to_bits(), s.batch_wait_s.to_bits());
            assert_eq!(p.load.to_bits(), s.load.to_bits());
            assert_eq!(p.slowdown.to_bits(), s.slowdown.to_bits());
            assert_eq!(pool.backlog_mmacs().to_bits(), single.backlog_mmacs().to_bits());
            assert!(pool.admitting());
            assert_eq!(pool.n_replicas(), 1);
        }
    }

    fn elastic(max: usize) -> ElasticParams {
        ElasticParams {
            autoscaler: AutoscalerParams {
                min_replicas: 1,
                max_replicas: max,
                warmup_s: 5.0,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn overload_grows_the_pool_and_drains_the_queue_faster() {
        let params = CloudParams::default();
        let cap = params.capacity_mmacs_per_s;
        let mut fixed = ReplicaPool::new(params, ElasticParams::default());
        let mut pool = ReplicaPool::new(params, elastic(4));
        for _ in 0..60 {
            fixed.advance_epoch(20_000, 2.0 * cap, 1.0);
            pool.advance_epoch(20_000, 2.0 * cap, 1.0);
        }
        assert!(pool.n_replicas() > 1, "sustained overload must scale up");
        assert!(
            pool.snapshot().queue_wait_s < fixed.snapshot().queue_wait_s,
            "elastic wait {} must beat fixed wait {}",
            pool.snapshot().queue_wait_s,
            fixed.snapshot().queue_wait_s
        );
    }

    #[test]
    fn warming_replicas_serve_nothing_until_ready() {
        let params = CloudParams::default();
        let cap = params.capacity_mmacs_per_s;
        let mut pool = ReplicaPool::new(params, elastic(2));
        // Push until the pool provisions a second replica.
        let mut epochs = 0;
        while pool.n_replicas() == 1 && epochs < 50 {
            pool.advance_epoch(20_000, 2.0 * cap, 1.0);
            epochs += 1;
        }
        assert_eq!(pool.n_replicas(), 2, "scale-up never happened");
        // During warm-up (5 s) only one replica is active.
        assert_eq!(pool.n_active(), 1);
        for _ in 0..5 {
            pool.advance_epoch(20_000, 2.0 * cap, 1.0);
        }
        assert_eq!(pool.n_active(), 2, "replica must join after warm-up");
    }

    #[test]
    fn idle_pool_scales_back_down_and_redistributes_backlog() {
        let params = CloudParams::default();
        let cap = params.capacity_mmacs_per_s;
        let mut pool = ReplicaPool::new(params, elastic(4));
        for _ in 0..40 {
            pool.advance_epoch(20_000, 2.5 * cap, 1.0);
        }
        let peak = pool.n_replicas();
        assert!(peak > 1);
        for _ in 0..400 {
            pool.advance_epoch(0, 0.0, 1.0);
        }
        assert_eq!(pool.n_replicas(), 1, "idle pool must retire extra replicas");
        assert!(pool.snapshot().queue_wait_s < 1e-6, "queue drained");
    }

    #[test]
    fn admission_flag_trips_above_the_bound_and_recovers() {
        let params = CloudParams::default();
        let cap = params.capacity_mmacs_per_s;
        let mut pool = ReplicaPool::new(
            params,
            ElasticParams { admit_backlog_s: 2.0, ..ElasticParams::default() },
        );
        assert!(pool.admitting());
        for _ in 0..10 {
            pool.advance_epoch(20_000, 3.0 * cap, 1.0);
        }
        assert!(!pool.admitting(), "deep backlog must trip admission control");
        for _ in 0..60 {
            pool.advance_epoch(0, 0.0, 1.0);
        }
        assert!(pool.admitting(), "drained pool must admit again");
    }

    #[test]
    fn adaptive_schedule_widens_the_batch_window_under_load() {
        let params = CloudParams::default();
        let cap = params.capacity_mmacs_per_s;
        let mut pool = ReplicaPool::new(
            params,
            ElasticParams { batch: BatchSchedule::Adaptive, ..ElasticParams::default() },
        );
        let idle_wait = pool.snapshot().batch_wait_s;
        for _ in 0..5 {
            pool.advance_epoch(20_000, 2.0 * cap, 1.0);
        }
        assert!(
            pool.snapshot().batch_wait_s > idle_wait,
            "window must widen under load: {} vs {}",
            pool.snapshot().batch_wait_s,
            idle_wait
        );
        // And narrow again once the load is gone and the queue drains.
        for _ in 0..200 {
            pool.advance_epoch(0, 0.0, 1.0);
        }
        assert_eq!(pool.snapshot().batch_wait_s.to_bits(), idle_wait.to_bits());
    }

    #[test]
    fn least_backlog_dispatch_balances_unequal_replicas() {
        let params = CloudParams::default();
        let cap = params.capacity_mmacs_per_s;
        let mk = |dispatch| {
            let mut e = elastic(2);
            e.dispatch = dispatch;
            e.autoscaler.min_replicas = 2;
            ReplicaPool::new(params, e)
        };
        let mut pool = mk(DispatchKind::LeastBacklog);
        assert_eq!(pool.n_active(), 2, "min_replicas pre-provisions the pool");
        // Odd job counts leave a remainder every epoch; least-backlog
        // must keep steering it to the lighter replica, so the pooled
        // queue stays no worse than round-robin's.
        let mut rr = mk(DispatchKind::RoundRobin);
        for _ in 0..30 {
            pool.advance_epoch(10_001, 2.2 * cap, 1.0);
            rr.advance_epoch(10_001, 2.2 * cap, 1.0);
        }
        assert!(pool.snapshot().queue_wait_s <= rr.snapshot().queue_wait_s + 1e-9);
    }

    #[test]
    fn pool_trajectory_is_deterministic() {
        let run = || {
            let params = CloudParams::default();
            let cap = params.capacity_mmacs_per_s;
            let mut pool = ReplicaPool::new(params, elastic(4));
            let mut traj = Vec::new();
            for &(jobs, macs) in &overload_epochs() {
                pool.advance_epoch(jobs, 1.5 * macs / cap * cap, 1.0);
                traj.push((pool.n_replicas(), pool.snapshot().queue_wait_s.to_bits()));
            }
            traj
        };
        assert_eq!(run(), run());
    }
}
