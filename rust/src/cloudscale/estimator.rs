//! Scalar Kalman-style estimator: the smoothing stage between raw
//! per-epoch measurements (pooled utilization, queue wait) and the
//! scaling rule. A one-dimensional Kalman filter with constant process
//! and measurement variance reduces to an EWMA whose gain adapts while
//! the variance converges — it reacts fast from a cold start, then
//! settles into steady smoothing. Pure f64 arithmetic, no RNG: the same
//! measurement sequence always produces the same estimate sequence,
//! which is what keeps the autoscaler trajectory seed-reproducible.

/// One-dimensional Kalman filter over a noisy scalar signal.
#[derive(Clone, Copy, Debug)]
pub struct Estimator {
    value: f64,
    variance: f64,
    /// How fast the underlying signal is allowed to drift per step.
    process_var: f64,
    /// How noisy one measurement is.
    measure_var: f64,
    primed: bool,
}

impl Estimator {
    pub fn new(process_var: f64, measure_var: f64) -> Self {
        assert!(process_var > 0.0 && measure_var > 0.0);
        Estimator { value: 0.0, variance: 0.0, process_var, measure_var, primed: false }
    }

    /// Fold one measurement; returns the updated estimate. The first
    /// measurement primes the filter directly (no stale-zero transient).
    pub fn update(&mut self, z: f64) -> f64 {
        if !self.primed {
            self.value = z;
            self.variance = self.measure_var;
            self.primed = true;
            return self.value;
        }
        self.variance += self.process_var;
        let gain = self.variance / (self.variance + self.measure_var);
        self.value += gain * (z - self.value);
        self.variance *= 1.0 - gain;
        self.value
    }

    /// Current estimate (0.0 before the first measurement).
    #[inline]
    pub fn value(&self) -> f64 {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_measurement_primes_the_filter() {
        let mut e = Estimator::new(0.05, 0.5);
        assert_eq!(e.value(), 0.0);
        assert_eq!(e.update(0.8), 0.8);
    }

    #[test]
    fn estimate_tracks_a_step_change() {
        let mut e = Estimator::new(0.05, 0.5);
        for _ in 0..20 {
            e.update(0.2);
        }
        assert!((e.value() - 0.2).abs() < 1e-6);
        for _ in 0..30 {
            e.update(0.9);
        }
        assert!((e.value() - 0.9).abs() < 0.05, "estimate {} lags the step", e.value());
    }

    #[test]
    fn smoothing_damps_single_spikes() {
        let mut e = Estimator::new(0.05, 0.5);
        for _ in 0..10 {
            e.update(0.3);
        }
        e.update(5.0); // one outlier epoch
        assert!(e.value() < 2.0, "one spike must not dominate: {}", e.value());
    }

    #[test]
    fn identical_inputs_give_identical_estimates() {
        let feed = |n: usize| {
            let mut e = Estimator::new(0.05, 0.5);
            for i in 0..n {
                e.update((i % 7) as f64 * 0.1);
            }
            e.value().to_bits()
        };
        assert_eq!(feed(50), feed(50));
    }
}
