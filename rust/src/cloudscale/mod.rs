//! Elastic cloud: a pool of autoscaled [`CloudModel`] replicas behind
//! deterministic dispatch, with estimator-driven scaling, admission
//! control and a load-dependent batch schedule.
//!
//! The fleet's fixed-capacity cloud (`fleet::cloud`) prices congestion
//! but can never *react* to it. This subsystem closes that loop the way
//! a serving cluster would:
//!
//! * a **replica pool** ([`ReplicaPool`]) generalizes the single
//!   `CloudModel` to N homogeneous replicas behind round-robin or
//!   least-backlog dispatch, folding per-replica queue state into one
//!   pooled [`CloudSnapshot`] so every existing policy keeps working
//!   unchanged;
//! * an **autoscaler** ([`Autoscaler`]) runs Kalman-style scalar
//!   estimators ([`Estimator`]) over pooled utilization and queue wait,
//!   feeding a [`ScalingRule`] (up/down thresholds, per-direction
//!   cooldowns, min/max bounds). New replicas serve nothing during a
//!   configurable warm-up lag — the scale-up-lag dynamic the `figure
//!   elastic` experiment measures;
//! * **admission control**: above a configurable backlog bound the pool
//!   stops admitting offloads for the next epoch; devices see a fast-fail
//!   `remote_failed` (distinct from a link timeout) so Q-learners and
//!   hysteresis retreat;
//! * a **load-dependent batch schedule** ([`BatchSchedule`]): the batch
//!   window widens stepwise under high utilization, trading per-request
//!   latency for throughput.
//!
//! Everything is evaluated **once per epoch on the main thread**, from
//! the same deterministically-reduced epoch aggregates the fixed cloud
//! already consumes — so the replica-count trajectory is a pure function
//! of the seed and is shard-invariant by construction. With the neutral
//! defaults (`min_replicas == max_replicas == 1`, admission off, static
//! batch schedule) the pool is bit-identical to the pre-existing single
//! `CloudModel`: the subsystem is strictly additive, pinned by the
//! driver-parity test in `tests/fleet.rs`.

pub mod autoscaler;
pub mod estimator;
pub mod pool;

pub use autoscaler::{Autoscaler, AutoscalerParams, ScalingRule};
pub use estimator::Estimator;
pub use pool::ReplicaPool;

use crate::fleet::{CloudModel, CloudSnapshot};

/// How the pool splits one epoch's offload traffic across active
/// replicas. Both variants are deterministic functions of the epoch
/// aggregate and replica state — no RNG, no thread ordering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchKind {
    /// Even split; the remainder jobs rotate across replicas between
    /// epochs (a persistent cursor plays the role of the round-robin
    /// pointer a per-request dispatcher would keep).
    RoundRobin,
    /// Even split; the remainder jobs go to the replicas with the least
    /// backlog (ties broken by replica id).
    LeastBacklog,
}

impl DispatchKind {
    pub fn parse(s: &str) -> Option<DispatchKind> {
        match s {
            "rr" | "round-robin" => Some(DispatchKind::RoundRobin),
            "least" | "least-backlog" => Some(DispatchKind::LeastBacklog),
            _ => None,
        }
    }
}

/// Load-dependent batch window schedule: a small stepwise lookup from
/// pooled utilization to a multiplier on the configured base window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchSchedule {
    /// Never touch the window (the neutral default — bit-identical to
    /// the fixed cloud).
    Static,
    /// Widen the window stepwise as utilization climbs: 1x below 0.5,
    /// 2x below 0.75, 3x below 0.9, 4x at saturation. Wider windows
    /// form bigger batches (higher effective capacity) at the price of
    /// batch-wait latency.
    Adaptive,
}

impl BatchSchedule {
    pub fn parse(s: &str) -> Option<BatchSchedule> {
        match s {
            "static" => Some(BatchSchedule::Static),
            "adaptive" => Some(BatchSchedule::Adaptive),
            _ => None,
        }
    }

    /// Window multiplier for a given pooled utilization.
    pub fn multiplier(&self, utilization: f64) -> f64 {
        match self {
            BatchSchedule::Static => 1.0,
            BatchSchedule::Adaptive => {
                if utilization < 0.5 {
                    1.0
                } else if utilization < 0.75 {
                    2.0
                } else if utilization < 0.9 {
                    3.0
                } else {
                    4.0
                }
            }
        }
    }
}

/// Everything elastic about the cloud, bundled so `FleetConfig` (and the
/// TOML `[cloud.autoscaler]` section) carries one field. The default is
/// **neutral**: one pinned replica, admission off, static batching —
/// exactly the pre-existing fixed-capacity cloud.
#[derive(Clone, Copy, Debug)]
pub struct ElasticParams {
    pub autoscaler: AutoscalerParams,
    pub dispatch: DispatchKind,
    /// Admission bound in seconds of pooled queue wait: above it the
    /// cloud rejects new offloads for the next epoch. `f64::INFINITY`
    /// disables admission control entirely.
    pub admit_backlog_s: f64,
    pub batch: BatchSchedule,
}

impl Default for ElasticParams {
    fn default() -> Self {
        ElasticParams {
            autoscaler: AutoscalerParams::default(),
            dispatch: DispatchKind::RoundRobin,
            admit_backlog_s: f64::INFINITY,
            batch: BatchSchedule::Static,
        }
    }
}

impl ElasticParams {
    /// True when every elastic mechanism is at its neutral setting (the
    /// pool then reduces to a single fixed `CloudModel`).
    pub fn is_neutral(&self) -> bool {
        self.autoscaler.min_replicas == 1
            && self.autoscaler.max_replicas == 1
            && self.admit_backlog_s.is_infinite()
            && self.batch == BatchSchedule::Static
    }

    pub fn validate(&self) -> Result<(), String> {
        let a = &self.autoscaler;
        if a.min_replicas < 1 {
            return Err("autoscaler min_replicas must be >= 1".into());
        }
        if a.max_replicas < a.min_replicas {
            return Err("autoscaler max_replicas must be >= min_replicas".into());
        }
        if !(a.warmup_s >= 0.0) {
            return Err("autoscaler warmup_s must be >= 0".into());
        }
        let r = &a.rule;
        if !(r.up_utilization > 0.0) || !(r.down_utilization > 0.0) {
            return Err("scaling thresholds must be > 0".into());
        }
        if r.down_utilization >= r.up_utilization {
            return Err("down_utilization must be below up_utilization".into());
        }
        if !(r.up_queue_wait_s > 0.0) {
            return Err("up_queue_wait_s must be > 0".into());
        }
        if !(r.up_cooldown_s >= 0.0) || !(r.down_cooldown_s >= 0.0) {
            return Err("cooldowns must be >= 0".into());
        }
        if !(self.admit_backlog_s > 0.0) {
            return Err("admit_backlog_s must be > 0 (inf disables admission control)".into());
        }
        Ok(())
    }
}

/// One replica: a full `CloudModel` plus the time it becomes ready.
/// During warm-up (`ready_at_s` in the future) the replica receives no
/// traffic and contributes nothing to the pooled snapshot.
#[derive(Clone, Debug)]
pub struct Replica {
    pub model: CloudModel,
    pub ready_at_s: f64,
}

/// The pooled congestion view one fleet epoch runs against: the frozen
/// snapshot plus the admission decision and the replica count, all fixed
/// at the epoch boundary.
#[derive(Clone, Copy, Debug)]
pub struct PoolView {
    pub snapshot: CloudSnapshot,
    /// False = the cloud fast-fails every offload this epoch.
    pub admitting: bool,
    /// Provisioned replicas (including any still warming up).
    pub replicas: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_elastic_params_are_neutral() {
        let p = ElasticParams::default();
        assert!(p.is_neutral());
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_params() {
        let mut p = ElasticParams::default();
        p.autoscaler.min_replicas = 0;
        assert!(p.validate().is_err());

        let mut p = ElasticParams::default();
        p.autoscaler.min_replicas = 4;
        p.autoscaler.max_replicas = 2;
        assert!(p.validate().is_err());

        let mut p = ElasticParams::default();
        p.autoscaler.rule.down_utilization = 0.9;
        p.autoscaler.rule.up_utilization = 0.5;
        assert!(p.validate().is_err());

        let mut p = ElasticParams::default();
        p.admit_backlog_s = 0.0;
        assert!(p.validate().is_err());

        let mut p = ElasticParams::default();
        p.autoscaler.warmup_s = -1.0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn batch_schedule_steps_widen_with_load() {
        let s = BatchSchedule::Adaptive;
        assert_eq!(s.multiplier(0.1), 1.0);
        assert_eq!(s.multiplier(0.6), 2.0);
        assert_eq!(s.multiplier(0.8), 3.0);
        assert_eq!(s.multiplier(1.5), 4.0);
        assert_eq!(BatchSchedule::Static.multiplier(1.5), 1.0);
    }

    #[test]
    fn dispatch_and_schedule_parse_cli_spellings() {
        assert_eq!(DispatchKind::parse("rr"), Some(DispatchKind::RoundRobin));
        assert_eq!(DispatchKind::parse("least-backlog"), Some(DispatchKind::LeastBacklog));
        assert!(DispatchKind::parse("random").is_none());
        assert_eq!(BatchSchedule::parse("adaptive"), Some(BatchSchedule::Adaptive));
        assert!(BatchSchedule::parse("wide").is_none());
    }
}
