//! Runtime benchmarks: PJRT artifact execution latency per model/precision
//! (the real-compute anchor), XLA compile cost, and the simulator's
//! per-inference step cost.
//!
//! Requires `make artifacts`.

use autoscale::configsys::runconfig::EnvKind;
use autoscale::coordinator::envs::Environment;
use autoscale::exec::latency::RunContext;
use autoscale::nn::zoo::by_name;
use autoscale::runtime::Engine;
use autoscale::types::{Action, DeviceId, Precision, ProcKind};
use autoscale::util::bench::{black_box, fmt_time, Bencher};

fn main() {
    let b = Bencher::quick();
    println!("{:40} {:>12} {:>12} {:>12}", "benchmark", "mean", "median", "p95");

    // Simulator step cost (pure L3 path, no PJRT).
    let mut env = Environment::build(DeviceId::Mi8Pro, EnvKind::S1NoVariance, 1);
    let nn = by_name("mobilenet_v2").unwrap();
    let ctx = RunContext::default();
    let r = b.bench("simulator_run (mobilenet_v2)", || {
        black_box(env.sim.run(nn, Action::local(ProcKind::Cpu, Precision::Fp32), &ctx));
    });
    println!("{}", r.report());

    // Real PJRT execution per model class.
    let Ok(mut engine) = Engine::from_default_manifest() else {
        println!("(artifacts not built; skipping PJRT benches — run `make artifacts`)");
        return;
    };
    for (model, prec) in [
        ("mobilenet_v1", Precision::Fp32),
        ("mobilenet_v1", Precision::Int8),
        ("mobilenet_v3", Precision::Fp32),
        ("inception_v1", Precision::Fp32),
        ("mobilebert", Precision::Fp32),
    ] {
        // compile cost (first load) measured separately
        let t0 = std::time::Instant::now();
        engine.load(model, prec).unwrap();
        let compile_s = t0.elapsed().as_secs_f64();
        let mut seed = 0u64;
        let r = b.bench(&format!("pjrt_execute {model}/{prec}"), || {
            seed += 1;
            black_box(engine.execute(model, prec, seed).unwrap());
        });
        println!("{}  (compile {})", r.report(), fmt_time(compile_s));
    }
}
