//! Runtime benchmarks — a thin wrapper over
//! [`autoscale::benchsuite::run_models_suite`] (shared with the `bench`
//! CLI subcommand): the simulator's per-inference step cost, plus PJRT
//! artifact execution latency per model/precision when `make artifacts`
//! has been run (those rows are optional).

use autoscale::benchsuite::{print_report, run_models_suite};
use autoscale::util::bench::Bencher;

fn main() {
    let report = run_models_suite(&Bencher::quick());
    print_report(&report);
    if report.entries.len() == 1 {
        println!("(artifacts not built; PJRT benches skipped — run `make artifacts`)");
    }
}
