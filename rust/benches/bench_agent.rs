//! Agent micro-benchmarks — the §6.3 runtime-overhead claims: Q-table
//! training step ~10.6 µs, trained-table selection ~7.3 µs, Q-table
//! memory ~0.4 MB. A thin wrapper over
//! [`autoscale::benchsuite::run_agent_suite`] (shared with the `bench`
//! CLI subcommand).

use autoscale::benchsuite::{print_report, qtable_footprint, run_agent_suite};
use autoscale::util::bench::Bencher;

fn main() {
    let (actions, kb) = qtable_footprint();
    println!("action catalogue: {actions} actions; q-table {kb} KB (paper: ~0.4 MB)");
    let (report, select_us, train_us) = run_agent_suite(&Bencher::default());
    print_report(&report);
    println!(
        "\nsummary: select {select_us:.2} us (paper 7.3 us), \
         train step {train_us:.2} us (paper 10.6 us)"
    );
    assert!(select_us < 50.0, "selection should stay in the paper's us band");
    assert!(train_us < 100.0, "training step should stay in the us band");
}
