//! Agent micro-benchmarks — the §6.3 runtime-overhead claims:
//! Q-table training step ~10.6 µs, trained-table selection ~7.3 µs,
//! Q-table memory ~0.4 MB.

use autoscale::agent::qlearn::AutoScaleAgent;
use autoscale::agent::state::{State, StateObs};
use autoscale::policy::action_catalogue;
use autoscale::device::presets::device;
use autoscale::interference::Interference;
use autoscale::nn::zoo::by_name;
use autoscale::types::DeviceId;
use autoscale::util::bench::{black_box, Bencher};

fn main() {
    let b = Bencher::default();
    let catalogue = action_catalogue(&device(DeviceId::Mi8Pro));
    println!(
        "action catalogue: {} actions; q-table {} KB (paper: ~0.4 MB)",
        catalogue.len(),
        catalogue.len() * autoscale::agent::state::STATE_CARDINALITY * 8 / 1024
    );
    let mut agent = AutoScaleAgent::new(catalogue, Default::default(), 7);
    let nn = by_name("mobilenet_v3").unwrap();
    let obs = StateObs::from_parts(nn, Interference::default(), -60.0, -55.0);
    let s = State::discretize(&obs);

    println!("{:40} {:>12} {:>12} {:>12}", "benchmark", "mean", "median", "p95");

    // ① state observation + discretization
    let r = b.bench("state_discretize", || {
        black_box(State::discretize(black_box(&obs)));
    });
    println!("{}", r.report());

    // ② selection from a trained table (paper: 7.3 µs)
    let r = b.bench("select_greedy (trained-table lookup)", || {
        black_box(agent.select_greedy(black_box(s)));
    });
    println!("{}", r.report());
    let select_us = r.median_s() * 1e6;

    // ③ full training step: select + TD update (paper: 10.6 µs)
    let r = b.bench("select+update (training step)", || {
        let (a, _) = agent.select(black_box(s));
        agent.update(s, a, black_box(0.5), s);
    });
    println!("{}", r.report());
    let train_us = r.median_s() * 1e6;

    // ④ q-table save/load round trip
    let path = std::env::temp_dir().join("bench_qtable.txt");
    let r = b.bench("qtable_save", || {
        agent.table.save(&path).unwrap();
    });
    println!("{}", r.report());

    println!(
        "\nsummary: select {select_us:.2} us (paper 7.3 us), train step {train_us:.2} us (paper 10.6 us)"
    );
    assert!(select_us < 50.0, "selection should stay in the paper's us band");
    assert!(train_us < 100.0, "training step should stay in the us band");
}
