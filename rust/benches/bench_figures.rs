//! Figure-regeneration benchmark — a thin wrapper over
//! [`autoscale::benchsuite::run_figures_suite`] (shared with the `bench`
//! CLI subcommand): runs every registered experiment in quick mode and
//! times it — one row per paper table/figure, proving each regenerates
//! end to end from a cold start.

use autoscale::benchsuite::{print_report, run_figures_suite};

fn main() {
    let report = run_figures_suite();
    print_report(&report);
    let total: f64 = report.entries.iter().map(|e| e.mean_s).sum();
    println!("total: {total:.1}s for {} experiments", report.entries.len());
}
