//! Figure-regeneration benchmark: runs every registered experiment in
//! quick mode and times it — one bench row per paper table/figure, proving
//! each regenerates end to end from a cold start.

use std::time::Instant;

use autoscale::experiments;

fn main() {
    println!("{:8} {:>10}  rows  experiment", "figure", "time");
    let mut total = 0.0;
    for e in experiments::registry() {
        let t0 = Instant::now();
        let tables = (e.run)(7, true);
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        let rows: usize = tables.iter().map(|t| t.rows.len()).sum();
        println!("{:8} {:>9.2}s {:>5}  {}", e.id, dt, rows, e.about);
        assert!(rows > 0, "{} produced no rows", e.id);
    }
    println!("total: {total:.1}s for {} experiments", experiments::registry().len());
}
