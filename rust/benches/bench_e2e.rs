//! End-to-end serving throughput bench — a thin wrapper over
//! [`autoscale::benchsuite::run_e2e_suite`] (shared with the `bench` CLI
//! subcommand): requests/second through the full coordinator loop
//! (observe → select → simulate-execute → reward → update), with and
//! without the real runtime engine attached. L3 must not be the
//! bottleneck. Writes `BENCH_e2e.json` into the working directory.

use std::path::Path;

use autoscale::benchsuite::{print_report, run_e2e_suite};

fn main() {
    let report = run_e2e_suite();
    print_report(&report);
    let sim = report
        .entries
        .iter()
        .find(|e| e.name.contains("coordinator sim"))
        .expect("the simulated-serving row always runs");
    assert!(
        sim.throughput_per_s.unwrap_or(0.0) > 1000.0,
        "L3 must not be a bottleneck"
    );
    if report.entries.len() == 1 {
        println!("(artifacts not built; PJRT serving bench skipped)");
    }
    match report.write(Path::new(".")) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", report.file_name()),
    }
}
