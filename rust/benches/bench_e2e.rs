//! End-to-end serving throughput bench: requests/second through the full
//! coordinator loop (observe → select → simulate-execute → reward →
//! update), with and without the real PJRT engine attached. L3 must not be
//! the bottleneck: the coordinator overhead is reported separately.

use autoscale::agent::qlearn::AutoScaleAgent;
use autoscale::configsys::runconfig::{EnvKind, RunConfig};
use autoscale::coordinator::envs::Environment;
use autoscale::coordinator::serve::{ServeConfig, Server};
use autoscale::policy::{action_catalogue, AutoScalePolicy};
use autoscale::runtime::Engine;
use autoscale::types::DeviceId;

fn run_serving(n: usize, with_engine: bool) -> (f64, usize) {
    let device = DeviceId::Mi8Pro;
    let catalogue = action_catalogue(&autoscale::device::presets::device(device));
    let agent = AutoScaleAgent::new(catalogue, Default::default(), 7);
    let mut cfg = RunConfig::default();
    cfg.device = device;
    let env = Environment::build(device, EnvKind::D3RandomWlan, 7);
    let mut engine_store;
    let mut server = Server::new(
        env,
        AutoScalePolicy::new(agent),
        ServeConfig { run: cfg, models: vec!["mobilenet_v1", "mobilenet_v3"] },
    );
    if with_engine {
        engine_store = match Engine::from_default_manifest() {
            Ok(e) => e,
            Err(_) => return (0.0, 0),
        };
        server = server.with_engine(&mut engine_store);
    }
    let t0 = std::time::Instant::now();
    let m = server.serve(n);
    (t0.elapsed().as_secs_f64(), m.n())
}

fn main() {
    // Pure-simulation loop: this is the coordinator-side cost.
    let (dt, n) = run_serving(3000, false);
    println!(
        "coordinator loop (simulated exec): {n} reqs in {dt:.2}s = {:.0} req/s ({:.1} us/req)",
        n as f64 / dt,
        dt / n as f64 * 1e6
    );
    assert!(n as f64 / dt > 1000.0, "L3 must not be a bottleneck");

    // With real PJRT execution on the request path.
    let (dt, n) = run_serving(200, true);
    if n > 0 {
        println!(
            "serving with real PJRT compute:    {n} reqs in {dt:.2}s = {:.0} req/s ({:.2} ms/req)",
            n as f64 / dt,
            dt / n as f64 * 1e3
        );
    } else {
        println!("(artifacts not built; PJRT serving bench skipped)");
    }
}
