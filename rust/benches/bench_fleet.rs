//! Fleet-simulator throughput bench: simulated requests/second through the
//! full multi-device loop (arrivals → policy → physics → shared-cloud
//! accounting), and the sharding speedup. Also asserts the determinism
//! contract cheaply, since a bench that drifts run-to-run is useless.
//!
//! Besides the human-readable report, writes `BENCH_fleet.json` so the
//! perf trajectory is machine-trackable PR over PR.

use autoscale::fleet::{run_fleet, FleetConfig};
use autoscale::util::bench::{black_box, Bencher};

fn cfg(devices: usize, requests: usize, shards: usize) -> FleetConfig {
    FleetConfig {
        devices,
        requests_per_device: requests,
        shards,
        rate_hz: 4.0,
        seed: 7,
        policy: "autoscale".to_string(),
        ..Default::default()
    }
}

/// One measured configuration, destined for BENCH_fleet.json.
struct JsonEntry {
    name: String,
    shards: usize,
    mean_s: f64,
    median_s: f64,
    p95_s: f64,
    requests_per_s: f64,
}

fn write_json(
    entries: &[JsonEntry],
    speedup: Option<f64>,
    fingerprint: u64,
) -> std::io::Result<()> {
    let mut rows = String::new();
    for (i, e) in entries.iter().enumerate() {
        let sep = if i + 1 < entries.len() { "," } else { "" };
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"shards\": {}, \"mean_s\": {:.6}, \
             \"median_s\": {:.6}, \"p95_s\": {:.6}, \"requests_per_s\": {:.1}}}{}\n",
            e.name, e.shards, e.mean_s, e.median_s, e.p95_s, e.requests_per_s, sep
        ));
    }
    let speedup_field = match speedup {
        Some(s) => format!("{s:.3}"),
        None => "null".to_string(),
    };
    let json = format!(
        "{{\n  \"bench\": \"fleet\",\n  \"entries\": [\n{rows}  ],\n  \
         \"sharding_speedup\": {speedup_field},\n  \
         \"fingerprint\": \"{fingerprint:016x}\"\n}}\n"
    );
    std::fs::write("BENCH_fleet.json", json)
}

fn main() {
    // One fleet run is a heavyweight iteration; keep the sample budget low.
    let b = Bencher::quick();
    println!("{:40} {:>12} {:>12} {:>12}", "benchmark", "mean", "median", "p95");

    let mut entries = Vec::new();
    let mut medians = Vec::new();
    for shards in [1usize, 4] {
        let c = cfg(128, 25, shards);
        let name = format!("fleet 128x25 shards={shards}");
        let r = b.bench(&name, || {
            black_box(run_fleet(black_box(&c)).unwrap());
        });
        println!("{}", r.report());
        let reqs = (128 * 25) as f64;
        println!("{:40} {:>10.0} requests/s simulated", "", reqs / r.median_s());
        entries.push(JsonEntry {
            name,
            shards,
            mean_s: r.mean_s(),
            median_s: r.median_s(),
            p95_s: r.p95_s(),
            requests_per_s: reqs / r.median_s(),
        });
        medians.push(r.median_s());
    }
    let speedup = (medians.len() == 2).then(|| medians[0] / medians[1]);
    if let Some(s) = speedup {
        println!("sharding speedup (1 -> 4 workers): {s:.2}x");
    }

    // Determinism spot-check: identical config+seed, identical fingerprint.
    let c = cfg(64, 20, 2);
    let f1 = run_fleet(&c).unwrap().metrics.fingerprint();
    let f2 = run_fleet(&c).unwrap().metrics.fingerprint();
    assert_eq!(f1, f2, "fleet runs must be deterministic");
    println!("fingerprint (64x20, shards=2): {f1:016x}");

    match write_json(&entries, speedup, f1) {
        Ok(()) => println!("wrote BENCH_fleet.json"),
        Err(e) => eprintln!("could not write BENCH_fleet.json: {e}"),
    }
}
