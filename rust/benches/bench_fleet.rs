//! Fleet-simulator throughput bench: simulated requests/second through the
//! full multi-device loop (arrivals → policy → physics → shared-cloud
//! accounting), and the sharding speedup. Also asserts the determinism
//! contract cheaply, since a bench that drifts run-to-run is useless.

use autoscale::fleet::{run_fleet, FleetConfig, FleetPolicyKind};
use autoscale::util::bench::{black_box, Bencher};

fn cfg(devices: usize, requests: usize, shards: usize) -> FleetConfig {
    FleetConfig {
        devices,
        requests_per_device: requests,
        shards,
        rate_hz: 4.0,
        seed: 7,
        policy: FleetPolicyKind::AutoScale,
        ..Default::default()
    }
}

fn main() {
    // One fleet run is a heavyweight iteration; keep the sample budget low.
    let b = Bencher::quick();
    println!("{:40} {:>12} {:>12} {:>12}", "benchmark", "mean", "median", "p95");

    let mut medians = Vec::new();
    for shards in [1usize, 4] {
        let c = cfg(128, 25, shards);
        let name = format!("fleet 128x25 shards={shards}");
        let r = b.bench(&name, || {
            black_box(run_fleet(black_box(&c)).unwrap());
        });
        println!("{}", r.report());
        let reqs = (128 * 25) as f64;
        println!("{:40} {:>10.0} requests/s simulated", "", reqs / r.median_s());
        medians.push(r.median_s());
    }
    if medians.len() == 2 {
        println!(
            "sharding speedup (1 -> 4 workers): {:.2}x",
            medians[0] / medians[1]
        );
    }

    // Determinism spot-check: identical config+seed, identical fingerprint.
    let c = cfg(64, 20, 2);
    let f1 = run_fleet(&c).unwrap().metrics.fingerprint();
    let f2 = run_fleet(&c).unwrap().metrics.fingerprint();
    assert_eq!(f1, f2, "fleet runs must be deterministic");
    println!("fingerprint (64x20, shards=2): {f1:016x}");
}
