//! Fleet-simulator throughput bench — a thin wrapper over
//! [`autoscale::benchsuite::run_fleet_suite`], the same suite the `bench`
//! CLI subcommand and the CI `bench-regression` job run, so this target
//! can never drift from what CI measures. Reports simulated
//! requests/second through the full multi-device loop plus the sharding
//! speedup, asserts determinism, and writes `BENCH_fleet.json` (the
//! machine-tracked perf trajectory) into the working directory.

use std::path::Path;

use autoscale::benchsuite::{print_report, run_fleet_suite, sharding_speedup};
use autoscale::util::bench::Bencher;

fn main() {
    let report = run_fleet_suite(&Bencher::quick(), false);
    print_report(&report);
    if let Some(s) = sharding_speedup(&report) {
        println!("sharding speedup (1 -> 4 workers): {s:.2}x");
    }
    match report.write(Path::new(".")) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", report.file_name()),
    }
}
