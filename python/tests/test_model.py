"""Model-zoo tests: Table 3 layer compositions, shapes, precision variants,
and quantization-error bounds across the full zoo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as zoo


@pytest.mark.parametrize("name", list(zoo.ZOO))
def test_layer_composition_matches_table3(name):
    spec = zoo.ZOO[name]
    assert (spec.s_conv, spec.s_fc, spec.s_rc) == zoo.TABLE3[name]


@pytest.mark.parametrize("name", list(zoo.ZOO))
def test_macs_and_bytes_positive(name):
    macs, byts = zoo.count_macs_bytes(zoo.ZOO[name])
    assert macs > 0 and byts > 0


# Forward passes through interpret-mode pallas are slow; run the full-zoo
# forward check on the three paper-representative models (Fig 2) plus both
# detection/NLP workload classes, and every precision on one light model.
FWD_MODELS = ["mobilenet_v1", "mobilenet_v3", "mobilebert", "ssd_mobilenet_v1"]


@pytest.mark.parametrize("name", FWD_MODELS)
def test_forward_shape_and_finite(name):
    fn, x, spec = zoo.make_model(name)
    (out,) = fn(x)
    assert out.ndim == 2 and out.shape[0] == 1
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("precision", zoo.PRECISIONS)
def test_precision_variants_run(precision):
    fn, x, _ = zoo.make_model("mobilenet_v1", precision)
    (out,) = fn(x)
    assert np.isfinite(np.asarray(out)).all()


def test_int8_close_to_fp32():
    """Quantization error at the logits stays small for a light model."""
    fn32, x, _ = zoo.make_model("mobilenet_v1", "fp32")
    fn8, _, _ = zoo.make_model("mobilenet_v1", "int8")
    o32 = np.asarray(fn32(x)[0])
    o8 = np.asarray(fn8(x)[0])
    denom = np.abs(o32).mean() + 1e-6
    assert np.abs(o32 - o8).mean() / denom < 0.15


def test_fp16_close_to_fp32():
    fn32, x, _ = zoo.make_model("mobilenet_v1", "fp32")
    fn16, _, _ = zoo.make_model("mobilenet_v1", "fp16")
    o32 = np.asarray(fn32(x)[0])
    o16 = np.asarray(fn16(x)[0])
    denom = np.abs(o32).mean() + 1e-6
    assert np.abs(o32 - o16).mean() / denom < 0.2


def test_forward_is_deterministic():
    fn, x, _ = zoo.make_model("mobilenet_v1")
    a = np.asarray(fn(x)[0])
    b = np.asarray(fn(x)[0])
    np.testing.assert_array_equal(a, b)


def test_distinct_seeds_give_distinct_params():
    fn_a, x, _ = zoo.make_model("mobilenet_v1", seed=0)
    fn_b, _, _ = zoo.make_model("mobilenet_v1", seed=1)
    assert not np.allclose(np.asarray(fn_a(x)[0]), np.asarray(fn_b(x)[0]))


def test_workload_classes():
    workloads = {s.workload for s in zoo.ZOO.values()}
    assert workloads == {"image_classification", "object_detection", "translation"}
    assert zoo.ZOO["mobilebert"].workload == "translation"


def test_sequence_model_input_shape():
    t, b, d = zoo.ZOO["mobilebert"].input_shape
    assert t > 1 and b >= 1 and d > 1
