"""Pallas kernels vs pure-jnp ref oracle — the CORE correctness signal.

hypothesis sweeps shapes/dtypes/activations; every kernel must match ref.py
to fp32 tolerance (int8 path compares against the identically-quantized ref).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as cv
from compile.kernels import lstm_cell as lc
from compile.kernels import matmul as mm
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)
ACTS = ("relu", "relu6", "hswish", "sigmoid", "tanh", "none")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


def assert_close(a, b, tol=2e-4):
    np.testing.assert_allclose(
        np.asarray(a, dtype=np.float32), np.asarray(b, dtype=np.float32),
        rtol=tol, atol=tol,
    )


# ---------------------------------------------------------------------------
# matmul family
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    assert_close(mm.matmul(x, w), ref.matmul(x, w))


@settings(**SETTINGS)
@given(
    m=st.integers(1, 32),
    k=st.integers(1, 32),
    n=st.integers(1, 32),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_matmul_bias_act_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    assert_close(
        mm.matmul_bias_act(x, w, b, act=act), ref.matmul_bias_act(x, w, b, act=act)
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 32),
    k=st.integers(1, 32),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_matmul_int8_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    wq, scale = mm.quantize_weight(w)
    assert_close(
        mm.matmul_int8(x, wq, scale, b), ref.matmul_int8(x, wq, scale, b)
    )


def test_matmul_tiled_grid_exercised():
    """Block smaller than the operand => multi-point grid, same numbers."""
    x = _rand(0, (64, 96))
    w = _rand(1, (96, 80))
    tiled = mm.matmul_f32(x, w, block_m=16, block_n=16, block_k=32)
    assert_close(tiled, ref.matmul(x, w))


def test_matmul_bf16_accumulates_fp32():
    x = _rand(0, (16, 64), jnp.bfloat16)
    w = _rand(1, (64, 16), jnp.bfloat16)
    out = mm.matmul(x, w)
    assert out.dtype == jnp.bfloat16
    assert_close(out, ref.matmul(x, w), tol=5e-2)  # bf16 mantissa


def test_quantize_weight_roundtrip_error_bounded():
    w = _rand(3, (32, 24))
    wq, scale = mm.quantize_weight(w)
    err = np.abs(np.asarray(wq, np.float32) * np.asarray(scale) - np.asarray(w))
    # max error is half an int8 step per channel
    assert (err <= np.asarray(scale) * 0.5 + 1e-6).all()
    assert wq.dtype == jnp.int8


def test_quantize_weight_zero_column():
    w = jnp.zeros((8, 4))
    wq, scale = mm.quantize_weight(w)
    assert np.asarray(wq).max() == 0
    assert (np.asarray(scale) == 1.0).all()  # guarded divide


def test_matmul_rejects_bad_shapes():
    with pytest.raises(AssertionError):
        mm.matmul(_rand(0, (4, 5)), _rand(1, (6, 7)))


# ---------------------------------------------------------------------------
# conv family
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.integers(4, 12),
    c=st.integers(1, 8),
    f=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_conv2d_matches_ref(h, c, f, k, stride, seed):
    x = _rand(seed, (1, h, h, c))
    w = _rand(seed + 1, (k, k, c, f)) * 0.3
    b = _rand(seed + 2, (f,))
    assert_close(
        cv.conv2d(x, w, b, stride=stride), ref.conv2d(x, w, b, stride=stride), tol=1e-3
    )


@settings(**SETTINGS)
@given(
    h=st.integers(4, 10),
    c=st.integers(1, 8),
    f=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_conv2d_int8_matches_ref(h, c, f, seed):
    x = _rand(seed, (1, h, h, c))
    w = _rand(seed + 1, (3, 3, c, f)) * 0.3
    b = _rand(seed + 2, (f,))
    wq, scale = mm.quantize_weight(w.reshape(9 * c, f))
    wq = wq.reshape(3, 3, c, f)
    assert_close(
        cv.conv2d_int8(x, wq, scale, b), ref.conv2d_int8(x, wq, scale, b), tol=1e-3
    )


@settings(**SETTINGS)
@given(
    h=st.integers(2, 10),
    c=st.integers(1, 12),
    f=st.integers(1, 12),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_pointwise_conv_matches_ref(h, c, f, act, seed):
    x = _rand(seed, (1, h, h, c))
    w = _rand(seed + 1, (c, f))
    b = _rand(seed + 2, (f,))
    assert_close(
        cv.pointwise_conv(x, w, b, act=act), ref.pointwise_conv(x, w, b, act=act)
    )


@settings(**SETTINGS)
@given(
    h=st.integers(4, 10),
    c=st.integers(1, 16),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_depthwise_conv_matches_ref(h, c, stride, seed):
    x = _rand(seed, (1, h, h, c))
    w = _rand(seed + 1, (3, 3, c)) * 0.3
    b = _rand(seed + 2, (c,))
    assert_close(
        cv.depthwise_conv(x, w, b, stride=stride),
        ref.depthwise_conv(x, w, b, stride=stride),
        tol=1e-3,
    )


def test_depthwise_channel_grid():
    """c > block => multi-point channel grid, numbers unchanged."""
    x = _rand(0, (1, 6, 6, 96))
    w = _rand(1, (3, 3, 96)) * 0.3
    b = _rand(2, (96,))
    assert_close(cv.depthwise_conv(x, w, b), ref.depthwise_conv(x, w, b), tol=1e-3)


def test_pools():
    x = _rand(0, (2, 8, 8, 4))
    assert cv.max_pool2(x).shape == (2, 4, 4, 4)
    assert cv.avg_pool_global(x).shape == (2, 4)
    np.testing.assert_allclose(
        np.asarray(cv.avg_pool_global(x)),
        np.asarray(x).mean(axis=(1, 2)),
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# lstm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    i=st.integers(1, 16),
    h=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_lstm_cell_matches_ref(b, i, h, seed):
    x = _rand(seed, (b, i))
    h0 = _rand(seed + 1, (b, h))
    c0 = _rand(seed + 2, (b, h))
    wx = _rand(seed + 3, (i, 4 * h)) * 0.5
    wh = _rand(seed + 4, (h, 4 * h)) * 0.5
    bias = _rand(seed + 5, (4 * h,))
    got_h, got_c = lc.lstm_cell(x, h0, c0, wx, wh, bias)
    want_h, want_c = ref.lstm_cell(x, h0, c0, wx, wh, bias)
    assert_close(got_h, want_h, tol=1e-3)
    assert_close(got_c, want_c, tol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(1, 8),
    h=st.integers(1, 12),
    seed=st.integers(0, 2**16),
)
def test_lstm_layer_matches_ref(t, h, seed):
    xs = _rand(seed, (t, 2, 8))
    wx = _rand(seed + 1, (8, 4 * h)) * 0.5
    wh = _rand(seed + 2, (h, 4 * h)) * 0.5
    b = _rand(seed + 3, (4 * h,))
    assert_close(lc.lstm_layer(xs, wx, wh, b), ref.lstm_layer(xs, wx, wh, b), tol=1e-3)


def test_lstm_cell_state_bounded():
    """|h| <= 1 always (o * tanh(c)); property of the fused gates."""
    x = _rand(0, (4, 8)) * 10
    h0 = _rand(1, (4, 8))
    c0 = _rand(2, (4, 8))
    wx = _rand(3, (8, 32))
    wh = _rand(4, (8, 32))
    b = _rand(5, (32,))
    got_h, _ = lc.lstm_cell(x, h0, c0, wx, wh, b)
    assert np.abs(np.asarray(got_h)).max() <= 1.0 + 1e-6


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

from compile.kernels import attention as attn


@settings(**SETTINGS)
@given(
    tq=st.integers(1, 24),
    tk=st.integers(1, 24),
    d=st.integers(1, 16),
    dv=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(tq, tk, d, dv, seed):
    q = _rand(seed, (tq, d))
    k = _rand(seed + 1, (tk, d))
    v = _rand(seed + 2, (tk, dv))
    assert_close(attn.attention(q, k, v), ref.attention(q, k, v), tol=1e-3)


def test_attention_query_blocks_exercised():
    """block_q smaller than Tq => multi-point grid, same numbers."""
    q = _rand(0, (32, 8))
    k = _rand(1, (16, 8))
    v = _rand(2, (16, 8))
    out = attn.attention(q, k, v, block_q=8)
    assert_close(out, ref.attention(q, k, v), tol=1e-3)


def test_attention_rows_are_convex_combinations():
    """Each output row lies in the convex hull of V's rows: max bound."""
    q = _rand(3, (6, 4)) * 3
    k = _rand(4, (10, 4))
    v = _rand(5, (10, 4))
    out = np.asarray(attn.attention(q, k, v), np.float32)
    vmax = np.asarray(v).max(axis=0)
    vmin = np.asarray(v).min(axis=0)
    assert (out <= vmax + 1e-4).all() and (out >= vmin - 1e-4).all()


def test_self_attention_block_matches_ref():
    x = _rand(6, (12, 8))
    ws = [_rand(7 + i, (8, 8)) * 0.5 for i in range(4)]
    assert_close(
        attn.self_attention_block(x, *ws), ref.self_attention_block(x, *ws), tol=1e-3
    )
