"""AOT path tests: lowering produces parseable HLO text + correct manifest
metadata, and the lowered computation has the expected entry signature.
"""

import json
import os

import pytest

from compile import aot
from compile import model as zoo


@pytest.fixture(scope="module")
def lowered_light():
    return aot.lower_model("mobilenet_v1", "fp32")


def test_hlo_text_structure(lowered_light):
    text, meta = lowered_light
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_hlo_text_keeps_large_constants():
    """Regression: the default printer elides weights as `constant({...})`,
    which the HLO parser silently zero-fills — models then emit all-zero
    logits from rust. print_large_constants=True must keep the data."""
    text, _ = aot.lower_model("mobilenet_v1", "fp32")
    assert "constant({...})" not in text
    assert "{..." not in text


def test_meta_fields(lowered_light):
    _, meta = lowered_light
    assert meta["name"] == "mobilenet_v1"
    assert meta["precision"] == "fp32"
    assert (meta["s_conv"], meta["s_fc"], meta["s_rc"]) == zoo.TABLE3["mobilenet_v1"]
    assert meta["macs"] > 0 and meta["bytes"] > 0
    assert meta["hlo_chars"] == len(lowered_light[0])


def test_int8_artifact_contains_s8(lowered_int8=None):
    text, meta = aot.lower_model("mobilenet_v1", "int8")
    assert "s8" in text  # int8 weights visible in the HLO
    assert meta["precision"] == "int8"


def test_fp16_artifact_contains_bf16():
    text, _ = aot.lower_model("mobilenet_v1", "fp16")
    assert "bf16" in text


def test_manifest_written(tmp_path):
    """End-to-end CLI: one light model, all precisions."""
    import subprocess, sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--models",
            "mobilenet_v1",
            "--precisions",
            "fp32,int8",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["models"]) == 2
    for m in manifest["models"]:
        assert (out / m["artifact"]).exists()


def test_sequence_model_lowers():
    text, meta = aot.lower_model("mobilebert", "fp32")
    assert text.startswith("HloModule")
    assert meta["s_rc"] == 24
    # lax.scan keeps the artifact small: one rolled loop, not 24 copies
    assert "while" in text
