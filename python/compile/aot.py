"""AOT driver: lower every (model, precision) to HLO TEXT + manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Usage:
    python -m compile.aot --out-dir ../artifacts [--models a,b] [--precisions p]

Outputs:
    artifacts/<model>_<precision>.hlo.txt   one per zoo entry x precision
    artifacts/manifest.json                 metadata consumed by rust nn/
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import model as zoo


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is ESSENTIAL: the default printer elides big
    weight tensors as `constant({...})`, which the HLO text parser silently
    reads back as zeros — producing models that output all-zero logits.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_model(name: str, precision: str) -> tuple:
    """Lower one zoo model at one precision; returns (hlo_text, meta)."""
    fn, x, spec = zoo.make_model(name, precision)
    t0 = time.time()
    lowered = jax.jit(fn).lower(x)
    text = to_hlo_text(lowered)
    elapsed = time.time() - t0
    macs, byts = zoo.count_macs_bytes(spec)
    meta = {
        "name": name,
        "precision": precision,
        "workload": spec.workload,
        "input_shape": list(spec.input_shape),
        "s_conv": spec.s_conv,
        "s_fc": spec.s_fc,
        "s_rc": spec.s_rc,
        "macs": macs,
        "bytes": byts,
        "lower_seconds": round(elapsed, 3),
        "hlo_chars": len(text),
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(zoo.ZOO))
    ap.add_argument("--precisions", default=",".join(zoo.PRECISIONS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"models": []}
    for name in args.models.split(","):
        for precision in args.precisions.split(","):
            text, meta = lower_model(name, precision)
            fname = f"{name}_{precision}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(text)
            meta["artifact"] = fname
            manifest["models"].append(meta)
            print(
                f"lowered {name:20s} {precision:5s} -> {fname}"
                f" ({meta['hlo_chars']} chars, {meta['lower_seconds']}s)"
            )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['models'])} artifacts")


if __name__ == "__main__":
    main()
