"""L2: the model zoo — the paper's 10 DNN workloads (Table 3) in JAX.

Every network is built from a small layer-descriptor IR and executed by
calling the L1 Pallas kernels (matmul / conv2d / depthwise / lstm_cell), so
each artifact's HLO carries the kernels' block schedules. Layer compositions
(S_CONV / S_FC / S_RC counts) match the paper's Table 3 exactly; channel
widths and input resolution are scaled down ("tiny" configs) so that CPU
interpret-mode execution is tractable — the rust exec/ layer rescales
measured latency onto simulated device profiles (see DESIGN.md §1).

Each model exists in three precision variants mirroring the paper's
quantization actions: fp32, fp16 (bf16 on TPU/MXU) and int8 (int8 weights,
dequant in-kernel).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import conv2d as cv
from .kernels import lstm_cell as lc
from .kernels import matmul as mm

PRECISIONS = ("fp32", "fp16", "int8")


# ---------------------------------------------------------------------------
# layer IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv:
    """Spatial KxK conv; counts toward S_CONV."""

    out_ch: int
    k: int = 3
    stride: int = 1
    act: str = "relu"


@dataclasses.dataclass(frozen=True)
class PwConv:
    """1x1 pointwise conv; counts toward S_CONV (it is a conv layer)."""

    out_ch: int
    act: str = "relu"


@dataclasses.dataclass(frozen=True)
class DwConv:
    """Depthwise KxK conv; counts toward S_CONV."""

    k: int = 3
    stride: int = 1
    act: str = "relu"


@dataclasses.dataclass(frozen=True)
class MaxPool:
    pass


@dataclasses.dataclass(frozen=True)
class GlobalPool:
    pass


@dataclasses.dataclass(frozen=True)
class Fc:
    """Fully-connected layer; counts toward S_FC."""

    out_dim: int
    act: str = "relu"


@dataclasses.dataclass(frozen=True)
class Lstm:
    """One recurrent layer over the sequence; counts toward S_RC."""

    hidden: int


Layer = object


# ---------------------------------------------------------------------------
# parameter initialization + forward execution
# ---------------------------------------------------------------------------


def init_params(layers: list, input_shape: tuple, key) -> list:
    """Build fp32 parameters for a layer stack given the model input shape."""
    params: list[dict] = []
    shape = input_shape
    for layer in layers:
        key, sub = jax.random.split(key)
        if isinstance(layer, Conv):
            n, h, w, c = shape
            std = (2.0 / (layer.k * layer.k * c)) ** 0.5
            params.append(
                {
                    "w": jax.random.normal(sub, (layer.k, layer.k, c, layer.out_ch))
                    * std,
                    "b": jnp.zeros((layer.out_ch,)),
                }
            )
            oh = (h + 2 * (layer.k // 2) - layer.k) // layer.stride + 1
            ow = (w + 2 * (layer.k // 2) - layer.k) // layer.stride + 1
            shape = (n, oh, ow, layer.out_ch)
        elif isinstance(layer, PwConv):
            n, h, w, c = shape
            std = (2.0 / c) ** 0.5
            params.append(
                {
                    "w": jax.random.normal(sub, (c, layer.out_ch)) * std,
                    "b": jnp.zeros((layer.out_ch,)),
                }
            )
            shape = (n, h, w, layer.out_ch)
        elif isinstance(layer, DwConv):
            n, h, w, c = shape
            params.append(
                {
                    "w": jax.random.normal(sub, (layer.k, layer.k, c)) * 0.3,
                    "b": jnp.zeros((c,)),
                }
            )
            shape = (
                n,
                (h + layer.stride - 1) // layer.stride,
                (w + layer.stride - 1) // layer.stride,
                c,
            )
        elif isinstance(layer, MaxPool):
            n, h, w, c = shape
            params.append({})
            shape = (n, h // 2, w // 2, c)
        elif isinstance(layer, GlobalPool):
            n, _, _, c = shape
            params.append({})
            shape = (n, c)
        elif isinstance(layer, Fc):
            if len(shape) == 4:  # implicit flatten
                n = shape[0]
                d = shape[1] * shape[2] * shape[3]
            else:
                n, d = shape[0], shape[-1]
            std = (2.0 / d) ** 0.5
            params.append(
                {
                    "w": jax.random.normal(sub, (d, layer.out_dim)) * std,
                    "b": jnp.zeros((layer.out_dim,)),
                }
            )
            shape = (n, layer.out_dim)
        elif isinstance(layer, Lstm):
            t, n, d = shape  # sequence models: (T, B, D)
            std = (1.0 / d) ** 0.5
            params.append(
                {
                    "wx": jax.random.normal(sub, (d, 4 * layer.hidden)) * std,
                    "wh": jax.random.normal(sub, (layer.hidden, 4 * layer.hidden))
                    * std,
                    "b": jnp.zeros((4 * layer.hidden,)),
                }
            )
            shape = (t, n, layer.hidden)
        else:
            raise TypeError(f"unknown layer {layer!r}")
    return params


def quantize_params(layers: list, params: list) -> list:
    """int8 variant: quantize every matmul-backed weight per-channel."""
    out = []
    for layer, p in zip(layers, params):
        if isinstance(layer, (PwConv, Fc)):
            wq, s = mm.quantize_weight(p["w"])
            out.append({"wq": wq, "scale": s, "b": p["b"]})
        elif isinstance(layer, Conv):
            kh, kw, c, f = p["w"].shape
            wq, s = mm.quantize_weight(p["w"].reshape(kh * kw * c, f))
            out.append({"wq": wq.reshape(kh, kw, c, f), "scale": s, "b": p["b"]})
        else:
            # depthwise / lstm / pool stay fp32 (the paper's INT8 executables
            # quantize the conv+fc compute)
            out.append(p)
    return out


def forward(layers: list, params: list, x: jax.Array, *, precision: str = "fp32"):
    """Run the layer stack, dispatching every hot layer to a Pallas kernel."""
    dtype = jnp.bfloat16 if precision == "fp16" else jnp.float32
    x = x.astype(dtype)
    for layer, p in zip(layers, params):
        if isinstance(layer, Conv):
            if precision == "int8" and "wq" in p:
                x = cv.conv2d_int8(
                    x, p["wq"], p["scale"], p["b"], stride=layer.stride, act=layer.act
                )
            else:
                x = cv.conv2d(
                    x,
                    p["w"].astype(dtype),
                    p["b"].astype(dtype),
                    stride=layer.stride,
                    act=layer.act,
                )
        elif isinstance(layer, PwConv):
            if precision == "int8" and "wq" in p:
                n, h, w_, c = x.shape
                out = mm.matmul_int8(
                    x.reshape(n * h * w_, c), p["wq"], p["scale"], p["b"], act=layer.act
                )
                x = out.reshape(n, h, w_, -1)
            else:
                x = cv.pointwise_conv(
                    x, p["w"].astype(dtype), p["b"].astype(dtype), act=layer.act
                )
        elif isinstance(layer, DwConv):
            x = cv.depthwise_conv(
                x,
                p["w"].astype(dtype),
                p["b"].astype(dtype),
                stride=layer.stride,
                act=layer.act,
            )
        elif isinstance(layer, MaxPool):
            x = cv.max_pool2(x)
        elif isinstance(layer, GlobalPool):
            x = cv.avg_pool_global(x)
        elif isinstance(layer, Fc):
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            if x.ndim == 3:  # sequence: classify the last step
                x = x[-1]
            if precision == "int8" and "wq" in p:
                x = mm.matmul_int8(x, p["wq"], p["scale"], p["b"], act=layer.act)
            else:
                x = mm.matmul_bias_act(
                    x, p["w"].astype(dtype), p["b"].astype(dtype), act=layer.act
                )
        elif isinstance(layer, Lstm):
            x = lc.lstm_layer(
                x, p["wx"].astype(dtype), p["wh"].astype(dtype), p["b"].astype(dtype)
            )
        else:
            raise TypeError(f"unknown layer {layer!r}")
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# model zoo — Table 3 layer compositions at tiny dims
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    workload: str  # image_classification | object_detection | translation
    layers: tuple
    input_shape: tuple  # (N,H,W,C) image or (T,B,D) sequence

    @property
    def s_conv(self) -> int:
        return sum(isinstance(l, (Conv, PwConv, DwConv)) for l in self.layers)

    @property
    def s_fc(self) -> int:
        return sum(isinstance(l, Fc) for l in self.layers)

    @property
    def s_rc(self) -> int:
        return sum(isinstance(l, Lstm) for l in self.layers)


def _inception_module(ch: int) -> list:
    """Tiny inception block: 1x1 / 3x3 / pooled-1x1 branches collapsed to a
    sequential conv stack with matching CONV-layer count (3 convs/module)."""
    return [PwConv(ch), Conv(ch, k=3), PwConv(ch)]


def _inverted_residual(ch: int, *, act: str = "relu6") -> list:
    """MobilenetV2/V3 inverted residual: expand pw + dw + project pw (3 convs)."""
    return [PwConv(ch * 2, act=act), DwConv(k=3, act=act), PwConv(ch, act="none")]


def _mbv1_block(ch: int, stride: int = 1) -> list:
    """MobilenetV1 depthwise-separable block (2 convs)."""
    return [DwConv(k=3, stride=stride), PwConv(ch)]


def _resnet_block(ch: int) -> list:
    """Bottleneck block collapsed sequentially: pw + 3x3 + pw (3 convs)."""
    return [PwConv(ch), Conv(ch, k=3), PwConv(ch, act="none")]


def _image(layers: list, n_cls: int = 10) -> tuple:
    return tuple(layers + [GlobalPool(), Fc(n_cls, act="none")])


def _build_zoo() -> dict:
    img = (1, 16, 16, 8)  # tiny input; exec/ rescales to device profiles
    seq = (12, 1, 16)  # (T, B, D) for MobileBERT
    zoo: dict[str, ModelSpec] = {}

    # InceptionV1: 49 CONV, 1 FC = stem(1) + 16 modules x 3 convs
    layers: list = [Conv(8, k=3, stride=2)]
    for _ in range(16):
        layers += _inception_module(8)
    zoo["inception_v1"] = ModelSpec(
        "inception_v1", "image_classification", _image(layers), img
    )

    # InceptionV3: 94 CONV, 1 FC = stem(1) + 31 modules x 3 convs
    layers = [Conv(8, k=3, stride=2)]
    for _ in range(31):
        layers += _inception_module(8)
    zoo["inception_v3"] = ModelSpec(
        "inception_v3", "image_classification", _image(layers), img
    )

    # MobilenetV1: 14 CONV, 1 FC = stem(1) + 6 dw-separable blocks x 2 + pw(1)
    layers = [Conv(8, k=3, stride=2)]
    for _ in range(6):
        layers += _mbv1_block(8)
    layers += [PwConv(16)]
    zoo["mobilenet_v1"] = ModelSpec(
        "mobilenet_v1", "image_classification", _image(layers), img
    )

    # MobilenetV2: 35 CONV, 1 FC = stem(1) + 11 inverted residuals x 3 + pw(1)
    layers = [Conv(8, k=3, stride=2)]
    for _ in range(11):
        layers += _inverted_residual(8)
    layers += [PwConv(16)]
    zoo["mobilenet_v2"] = ModelSpec(
        "mobilenet_v2", "image_classification", _image(layers), img
    )

    # MobilenetV3: 23 CONV, 20 FC = stem(1) + 7 inv-res x 3 (hswish) + pw(1),
    # then 19 FC squeeze-excite-style head + classifier FC
    layers = [Conv(8, k=3, stride=2, act="hswish")]
    for _ in range(7):
        layers += _inverted_residual(8, act="hswish")
    layers += [PwConv(16, act="hswish"), GlobalPool()]
    for _ in range(19):
        layers += [Fc(16, act="hswish")]
    layers += [Fc(10, act="none")]
    zoo["mobilenet_v3"] = ModelSpec(
        "mobilenet_v3", "image_classification", tuple(layers), img
    )

    # Resnet50: 53 CONV, 1 FC = stem(1) + maxpool + 17 bottlenecks x 3 + pw(1)
    layers = [Conv(8, k=3, stride=2), MaxPool()]
    for _ in range(17):
        layers += _resnet_block(8)
    layers += [PwConv(16)]
    zoo["resnet50"] = ModelSpec("resnet50", "image_classification", _image(layers), img)

    # SSD MobilenetV1: 19 CONV, 1 FC = stem(1) + 7 blocks x 2 + 4 head convs
    layers = [Conv(8, k=3, stride=2)]
    for _ in range(7):
        layers += _mbv1_block(8)
    layers += [Conv(8, k=3), PwConv(8), Conv(8, k=3), PwConv(8)]
    zoo["ssd_mobilenet_v1"] = ModelSpec(
        "ssd_mobilenet_v1", "object_detection", _image(layers), img
    )

    # SSD MobilenetV2: 52 CONV, 1 FC = stem(1) + 15 inv-res x 3 + 6 head convs
    layers = [Conv(8, k=3, stride=2)]
    for _ in range(15):
        layers += _inverted_residual(8)
    layers += [
        Conv(8, k=3),
        PwConv(8),
        Conv(8, k=3),
        PwConv(8),
        Conv(8, k=3),
        PwConv(8),
    ]
    zoo["ssd_mobilenet_v2"] = ModelSpec(
        "ssd_mobilenet_v2", "object_detection", _image(layers), img
    )

    # SSD MobilenetV3: 28 CONV, 20 FC = stem(1) + 7 inv-res x 3 (hswish)
    #   + 6 head convs, then 19 FC SE-head + 1 classifier FC
    layers = [Conv(8, k=3, stride=2, act="hswish")]
    for _ in range(7):
        layers += _inverted_residual(8, act="hswish")
    layers += [
        Conv(8, k=3),
        PwConv(8),
        Conv(8, k=3),
        PwConv(8),
        Conv(8, k=3),
        PwConv(8),
    ]
    layers += [GlobalPool()]
    for _ in range(19):
        layers += [Fc(16, act="hswish")]
    layers += [Fc(10, act="none")]
    zoo["ssd_mobilenet_v3"] = ModelSpec(
        "ssd_mobilenet_v3", "object_detection", tuple(layers), img
    )

    # MobileBERT: 0 CONV, 1 FC, 24 RC
    layers = [Lstm(16) for _ in range(24)] + [Fc(16, act="none")]
    zoo["mobilebert"] = ModelSpec("mobilebert", "translation", tuple(layers), seq)

    return zoo


ZOO: dict = _build_zoo()

# Paper Table 3 — used by tests to cross-check the zoo's layer compositions.
TABLE3 = {
    "inception_v1": (49, 1, 0),
    "inception_v3": (94, 1, 0),
    "mobilenet_v1": (14, 1, 0),
    "mobilenet_v2": (35, 1, 0),
    "mobilenet_v3": (23, 20, 0),
    "resnet50": (53, 1, 0),
    "ssd_mobilenet_v1": (19, 1, 0),
    "ssd_mobilenet_v2": (52, 1, 0),
    "ssd_mobilenet_v3": (28, 20, 0),
    "mobilebert": (0, 1, 24),
}


def make_model(name: str, precision: str = "fp32", seed: int = 0):
    """Return (forward_fn, example_input, spec) for a zoo model + precision."""
    spec = ZOO[name]
    key = jax.random.PRNGKey(seed)
    params = init_params(list(spec.layers), spec.input_shape, key)
    if precision == "int8":
        params = quantize_params(list(spec.layers), params)

    def fn(x):
        return (forward(list(spec.layers), params, x, precision=precision),)

    x = jax.random.normal(jax.random.PRNGKey(seed + 1), spec.input_shape)
    return fn, x, spec


# ---------------------------------------------------------------------------
# MAC / byte accounting (feeds the manifest and the rust exec/ model)
# ---------------------------------------------------------------------------


def count_macs_bytes(spec: ModelSpec) -> tuple:
    """Analytic MACs and parameter+activation bytes for one inference."""
    macs = 0
    byts = 0
    shape = spec.input_shape
    for layer in spec.layers:
        if isinstance(layer, Conv):
            n, h, w, c = shape
            oh = (h + layer.stride - 1) // layer.stride
            ow = (w + layer.stride - 1) // layer.stride
            macs += n * oh * ow * layer.k * layer.k * c * layer.out_ch
            byts += (
                layer.k * layer.k * c * layer.out_ch * 4
                + n * oh * ow * layer.out_ch * 4
            )
            shape = (n, oh, ow, layer.out_ch)
        elif isinstance(layer, PwConv):
            n, h, w, c = shape
            macs += n * h * w * c * layer.out_ch
            byts += c * layer.out_ch * 4 + n * h * w * layer.out_ch * 4
            shape = (n, h, w, layer.out_ch)
        elif isinstance(layer, DwConv):
            n, h, w, c = shape
            macs += n * h * w * layer.k * layer.k * c
            byts += layer.k * layer.k * c * 4 + n * h * w * c * 4
            shape = (
                n,
                (h + layer.stride - 1) // layer.stride,
                (w + layer.stride - 1) // layer.stride,
                c,
            )
        elif isinstance(layer, MaxPool):
            n, h, w, c = shape
            shape = (n, h // 2, w // 2, c)
        elif isinstance(layer, GlobalPool):
            n, _, _, c = shape
            shape = (n, c)
        elif isinstance(layer, Fc):
            if len(shape) == 4:
                n, d = shape[0], shape[1] * shape[2] * shape[3]
            elif len(shape) == 3:
                n, d = shape[1], shape[-1]
            else:
                n, d = shape
            macs += n * d * layer.out_dim
            byts += d * layer.out_dim * 4 + n * layer.out_dim * 4
            shape = (n, layer.out_dim)
        elif isinstance(layer, Lstm):
            t, n, d = shape
            macs += t * n * (d + layer.hidden) * 4 * layer.hidden
            byts += (d + layer.hidden) * 4 * layer.hidden * 4 + t * n * layer.hidden * 4
            shape = (t, n, layer.hidden)
    return macs, byts
