"""L1 Pallas kernels: convolution family.

Spatial convs lower to im2col + the tiled Pallas matmul (the standard mobile
inference lowering — SNPE/TVM do the same on HVX/GPU); pointwise (1x1) convs
skip im2col and call the fused matmul directly; depthwise convs get their own
Pallas kernel gridded over channels (no channel mixing, MAC-light but
bandwidth-heavy — exactly why they behave differently in the paper's Fig 3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm

INTERPRET = True


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, pad: int) -> jax.Array:
    """(N, H, W, C) -> (N*OH*OW, KH*KW*C) patch matrix."""
    n, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    # Gather patches with static slices: small K so this unrolls to KH*KW
    # strided slices, which XLA fuses into a single gather-free loop nest.
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = x[:, di : di + stride * oh : stride, dj : dj + stride * ow : stride, :]
            cols.append(sl)
    patches = jnp.concatenate(cols, axis=-1)  # (N, OH, OW, KH*KW*C)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    pad: int | None = None,
    act: str = "relu",
) -> jax.Array:
    """Spatial conv: x (N,H,W,C), w (KH,KW,C,F), b (F,) -> (N,OH,OW,F).

    im2col (jnp, fused by XLA) + Pallas fused matmul epilogue.
    """
    kh, kw, c, f = w.shape
    if pad is None:
        pad = kh // 2  # 'same' for stride 1
    cols, (n, oh, ow) = _im2col(x, kh, kw, stride, pad)
    w2 = w.reshape(kh * kw * c, f)
    out = mm.matmul_bias_act(cols, w2, b, act=act)
    return out.reshape(n, oh, ow, f)


def conv2d_int8(
    x: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    pad: int | None = None,
    act: str = "relu",
) -> jax.Array:
    """INT8-weight spatial conv (paper's CPU INT8 / DSP executables)."""
    kh, kw, c, f = w_q.shape
    if pad is None:
        pad = kh // 2
    cols, (n, oh, ow) = _im2col(x, kh, kw, stride, pad)
    w2 = w_q.reshape(kh * kw * c, f)
    out = mm.matmul_int8(cols, w2, scale, b, act=act)
    return out.reshape(n, oh, ow, f)


def pointwise_conv(
    x: jax.Array, w: jax.Array, b: jax.Array, *, act: str = "relu"
) -> jax.Array:
    """1x1 conv: x (N,H,W,C), w (C,F) -> (N,H,W,F). Pure matmul, no im2col."""
    n, h, w_, c = x.shape
    out = mm.matmul_bias_act(x.reshape(n * h * w_, c), w, b, act=act)
    return out.reshape(n, h, w_, -1)


# ---------------------------------------------------------------------------
# depthwise conv — dedicated Pallas kernel
# ---------------------------------------------------------------------------


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int, act: str):
    """One grid point = one channel block; conv is unrolled over the KHxKW taps.

    x_ref: (N, H+2p, W+2p, BC) padded input block
    w_ref: (KH, KW, BC), b_ref: (BC,), o_ref: (N, OH, OW, BC)
    stride handled by caller slicing (stride=1 kernel; stride-2 layers
    subsample the output outside — bandwidth shape is identical).
    """
    _, oh, ow, _ = o_ref.shape
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for di in range(kh):
        for dj in range(kw):
            patch = x_ref[:, di : di + oh, dj : dj + ow, :].astype(jnp.float32)
            acc += patch * w_ref[di, dj, :].astype(jnp.float32)
    acc += b_ref[...].astype(jnp.float32)
    o_ref[...] = mm._apply_act(acc, act).astype(o_ref.dtype)


def depthwise_conv(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    act: str = "relu",
) -> jax.Array:
    """Depthwise conv: x (N,H,W,C), w (KH,KW,C), b (C,) -> (N,OH,OW,C).

    Gridded over channel blocks: each VMEM-resident block convolves
    independently (the Mobilenet depthwise stage).
    """
    n, h, w_, c = x.shape
    kh, kw, c2 = w.shape
    assert c == c2
    pad = kh // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    bc = mm._pick_block(c, 32)
    grid = (c // bc,)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, h + 2 * pad, w_ + 2 * pad, bc), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((kh, kw, bc), lambda i: (0, 0, i)),
            pl.BlockSpec((bc,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n, h, w_, bc), lambda i: (0, 0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((n, h, w_, c), x.dtype),
        interpret=INTERPRET,
    )(xp, w, b)
    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out


# ---------------------------------------------------------------------------
# pooling (jnp — not a hot spot; kept here so the model zoo has one home)
# ---------------------------------------------------------------------------


def avg_pool_global(x: jax.Array) -> jax.Array:
    """Global average pool: (N,H,W,C) -> (N,C)."""
    return jnp.mean(x, axis=(1, 2))


def max_pool2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max pool."""
    n, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return jnp.max(x, axis=(2, 4))
