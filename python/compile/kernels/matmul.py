"""L1 Pallas kernels: tiled matmul family.

These kernels are the compute hot-spot of every model in the zoo: FC layers,
1x1 (pointwise) convolutions and im2col'd spatial convolutions all lower to
the tiled matmul below. LSTM cells fuse four of them (see lstm_cell.py).

Hardware adaptation (paper -> TPU/Pallas): the paper's quantized executables
tile conv/FC onto Hexagon HVX vector tiles with a software-managed scratchpad.
The Pallas analogue is BlockSpec tiling into VMEM with a (M, N, K) grid; the
MXU wants multiples of (8, 128) so block shapes are padded toward those when
the model dims allow. INT8 on DSP / FP16 on GPU map to the `int8` dequant
variant and bf16 inputs respectively.

All kernels MUST run under interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); `INTERPRET` below is flipped only by TPU builds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: interpret-mode only (see module docstring).


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target.

    Keeps the grid exact (no masking needed) while biasing toward
    MXU-friendly tile sizes for the common power-of-two model dims.
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


# ---------------------------------------------------------------------------
# fp32 / bf16 tiled matmul
# ---------------------------------------------------------------------------


def _matmul_noacc_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """Accumulate directly into the output block (fp32 output path)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(o_ref.dtype),
        w_ref[...].astype(o_ref.dtype),
        preferred_element_type=o_ref.dtype,
    )


def matmul_f32(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Tiled fp32 matmul accumulating in the output block (no scratch).

    This is the variant the model zoo uses: portable across jax versions
    (no scratch_shapes), still expresses the HBM->VMEM block schedule.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    n_k = _cdiv(k, bk)
    grid = (_cdiv(m, bm), _cdiv(n, bn), n_k)
    out_dtype = jnp.promote_types(x.dtype, jnp.float32)
    return pl.pallas_call(
        functools.partial(_matmul_noacc_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=INTERPRET,
    )(x, w).astype(x.dtype)


# Public alias: the model zoo and tests use `matmul`.
matmul = matmul_f32


# ---------------------------------------------------------------------------
# fused bias + activation epilogue
# ---------------------------------------------------------------------------


def _apply_act(v, act: str):
    if act == "relu":
        return jnp.maximum(v, 0.0)
    if act == "relu6":
        return jnp.clip(v, 0.0, 6.0)
    if act == "hswish":
        return v * jnp.clip(v + 3.0, 0.0, 6.0) / 6.0
    if act == "sigmoid":
        return jax.nn.sigmoid(v)
    if act == "tanh":
        return jnp.tanh(v)
    if act == "none":
        return v
    raise ValueError(f"unknown activation {act!r}")


def _matmul_bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(o_ref.dtype),
        w_ref[...].astype(o_ref.dtype),
        preferred_element_type=o_ref.dtype,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...].astype(o_ref.dtype), act)


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Fused `act(x @ w + b)` — the FC / pointwise-conv workhorse.

    The epilogue (bias add + activation) runs on the final K grid step so the
    output block is written exactly once after accumulation — the Pallas
    spelling of an XLA fused epilogue.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    n_k = _cdiv(k, bk)
    grid = (_cdiv(m, bm), _cdiv(n, bn), n_k)
    out_dtype = jnp.promote_types(x.dtype, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=INTERPRET,
    )(x, w, b)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# int8-dequant matmul (DSP INT8 / CPU INT8 analogue)
# ---------------------------------------------------------------------------


def _matmul_int8_kernel(x_ref, wq_ref, scale_ref, b_ref, o_ref, *, n_k: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Dequantize the weight tile in VMEM: per-output-channel scale.
    w = wq_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...].astype(o_ref.dtype), act)


def matmul_int8(
    x: jax.Array,
    w_q: jax.Array,
    scale: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """`act(x @ dequant(w_q, scale) + b)` with int8 weights.

    w_q: (K, N) int8, scale: (N,) fp32 per-output-channel. Models the paper's
    INT8 quantized executables (CPU INT8 / DSP): weights live in memory at
    8 bits (4x bandwidth saving — reflected in the exec/ latency model) and
    are dequantized tile-by-tile inside the kernel.
    """
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2 and scale.shape == (n,) and b.shape == (n,)
    assert w_q.dtype == jnp.int8, w_q.dtype
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    n_k = _cdiv(k, bk)
    grid = (_cdiv(m, bm), _cdiv(n, bn), n_k)
    out = pl.pallas_call(
        functools.partial(_matmul_int8_kernel, n_k=n_k, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w_q, scale, b)
    return out.astype(x.dtype)


def quantize_weight(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8 quantization of a (K, N) weight."""
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    w_q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)
