"""L1 Pallas kernel: fused LSTM cell (the paper's RC-layer hot spot).

MobileBERT's recurrent/attention layers are the paper's translation workload;
per Section 2.1 its RC layers (LSTM, attention) are the most compute- and
memory-intensive layer class. We implement the cell as one fused Pallas
kernel: both gate matmuls ((B,I)@(I,4H) and (B,H)@(H,4H)), the gate
nonlinearities, and the state update happen in VMEM without round-tripping
gate tensors through HBM — the TPU analogue of the fused recurrent cells
mobile stacks ship for DSPs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, ho_ref, co_ref):
    """Single grid point: the whole cell for one batch block.

    x: (B, I), h: (B, H), c: (B, H), wx: (I, 4H), wh: (H, 4H), b: (4H,)
    Gate layout along the 4H axis: [i, f, g, o].
    """
    z = (
        jnp.dot(
            x_ref[...].astype(jnp.float32),
            wx_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        + jnp.dot(
            h_ref[...].astype(jnp.float32),
            wh_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...].astype(jnp.float32)
    )
    hh = z.shape[-1] // 4
    i = jax.nn.sigmoid(z[:, 0 * hh : 1 * hh])
    f = jax.nn.sigmoid(z[:, 1 * hh : 2 * hh])
    g = jnp.tanh(z[:, 2 * hh : 3 * hh])
    o = jax.nn.sigmoid(z[:, 3 * hh : 4 * hh])
    c_new = f * c_ref[...].astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    ho_ref[...] = h_new.astype(ho_ref.dtype)
    co_ref[...] = c_new.astype(co_ref.dtype)


def lstm_cell(
    x: jax.Array,
    h: jax.Array,
    c: jax.Array,
    wx: jax.Array,
    wh: jax.Array,
    b: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Fused LSTM cell. Shapes: x (B,I), h/c (B,H), wx (I,4H), wh (H,4H), b (4H,).

    Returns (h_new, c_new). Whole-cell fusion: at the tiny model-zoo dims the
    entire cell fits in VMEM, so the grid is a single point; larger H would
    grid over batch blocks with the same kernel.
    """
    bsz, isz = x.shape
    _, hsz = h.shape
    assert wx.shape == (isz, 4 * hsz) and wh.shape == (hsz, 4 * hsz)
    assert b.shape == (4 * hsz,)
    h_new, c_new = pl.pallas_call(
        _lstm_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((bsz, isz), lambda i: (0, 0)),
            pl.BlockSpec((bsz, hsz), lambda i: (0, 0)),
            pl.BlockSpec((bsz, hsz), lambda i: (0, 0)),
            pl.BlockSpec((isz, 4 * hsz), lambda i: (0, 0)),
            pl.BlockSpec((hsz, 4 * hsz), lambda i: (0, 0)),
            pl.BlockSpec((4 * hsz,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bsz, hsz), lambda i: (0, 0)),
            pl.BlockSpec((bsz, hsz), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hsz), x.dtype),
            jax.ShapeDtypeStruct((bsz, hsz), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, h, c, wx, wh, b)
    return h_new, c_new


def lstm_layer(
    xs: jax.Array,
    wx: jax.Array,
    wh: jax.Array,
    b: jax.Array,
) -> jax.Array:
    """Run the fused cell over a sequence: xs (T,B,I) -> hs (T,B,H).

    Uses lax.scan so the lowered HLO is a single rolled loop (one cell body),
    keeping artifact size independent of sequence length.
    """
    t, bsz, _ = xs.shape
    hsz = wh.shape[0]
    h0 = jnp.zeros((bsz, hsz), xs.dtype)
    c0 = jnp.zeros((bsz, hsz), xs.dtype)

    def step(carry, x):
        h, c = carry
        h, c = lstm_cell(x, h, c, wx, wh, b)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs
