"""Pure-jnp oracle for every Pallas kernel — THE correctness signal.

Each function mirrors a kernel in matmul.py / conv2d.py / lstm_cell.py using
only jax.numpy (no pallas), so pytest can assert_allclose kernel vs ref over
hypothesis-swept shapes and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _apply_act(v, act: str):
    if act == "relu":
        return jnp.maximum(v, 0.0)
    if act == "relu6":
        return jnp.clip(v, 0.0, 6.0)
    if act == "hswish":
        return v * jnp.clip(v + 3.0, 0.0, 6.0) / 6.0
    if act == "sigmoid":
        return jax.nn.sigmoid(v)
    if act == "tanh":
        return jnp.tanh(v)
    if act == "none":
        return v
    raise ValueError(act)


def matmul(x, w):
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def matmul_bias_act(x, w, b, *, act="relu"):
    out = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    return _apply_act(out, act).astype(x.dtype)


def matmul_int8(x, w_q, scale, b, *, act="relu"):
    w = w_q.astype(jnp.float32) * scale.astype(jnp.float32)
    out = x.astype(jnp.float32) @ w + b.astype(jnp.float32)
    return _apply_act(out, act).astype(x.dtype)


def conv2d(x, w, b, *, stride=1, pad=None, act="relu"):
    kh, kw, _, _ = w.shape
    if pad is None:
        pad = kh // 2
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b.astype(jnp.float32)
    return _apply_act(out, act).astype(x.dtype)


def conv2d_int8(x, w_q, scale, b, *, stride=1, pad=None, act="relu"):
    w = w_q.astype(jnp.float32) * scale.astype(jnp.float32)
    return conv2d(x, w, b, stride=stride, pad=pad, act=act)


def pointwise_conv(x, w, b, *, act="relu"):
    n, h, w_, c = x.shape
    out = matmul_bias_act(x.reshape(n * h * w_, c), w, b, act=act)
    return out.reshape(n, h, w_, -1)


def depthwise_conv(x, w, b, *, stride=1, act="relu"):
    kh, kw, c = w.shape
    pad = kh // 2
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.reshape(kh, kw, 1, c).astype(jnp.float32),
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    out = _apply_act(out + b.astype(jnp.float32), act)
    if stride > 1:
        out = out[:, ::stride, ::stride, :]
    return out.astype(x.dtype)


def lstm_cell(x, h, c, wx, wh, b):
    z = (
        x.astype(jnp.float32) @ wx.astype(jnp.float32)
        + h.astype(jnp.float32) @ wh.astype(jnp.float32)
        + b.astype(jnp.float32)
    )
    hh = z.shape[-1] // 4
    i = jax.nn.sigmoid(z[:, :hh])
    f = jax.nn.sigmoid(z[:, hh : 2 * hh])
    g = jnp.tanh(z[:, 2 * hh : 3 * hh])
    o = jax.nn.sigmoid(z[:, 3 * hh :])
    c_new = f * c.astype(jnp.float32) + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new.astype(x.dtype), c_new.astype(x.dtype)


def lstm_layer(xs, wx, wh, b):
    t, bsz, _ = xs.shape
    hsz = wh.shape[0]
    h = jnp.zeros((bsz, hsz), xs.dtype)
    c = jnp.zeros((bsz, hsz), xs.dtype)
    hs = []
    for step in range(t):
        h, c = lstm_cell(xs[step], h, c, wx, wh, b)
        hs.append(h)
    return jnp.stack(hs)


def attention(q, k, v):
    d = q.shape[-1]
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / d**0.5
    p = jax.nn.softmax(logits, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)


def self_attention_block(x, wq, wk, wv, wo):
    q = x @ wq
    k = x @ wk
    v = x @ wv
    return x + attention(q, k, v) @ wo
