"""L1 Pallas kernel: single-head scaled-dot-product attention.

The paper's RC layer class covers "LSTM and attention" (§2.1); MobileBERT's
real blocks are attention+FFN. The zoo models RC layers with the fused LSTM
cell (lstm_cell.py); this kernel provides the attention flavour so the RC
class is covered end to end at the kernel level, with the same VMEM-tiling
treatment: one grid point processes one query block against the full K/V
(small sequence lengths on-device), fusing QK^T, the softmax and the PV
product without materializing the attention matrix in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One query block vs full K/V: out = softmax(q k^T * scale) v."""
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # numerically stable softmax in VMEM
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, block_q: int = 64) -> jax.Array:
    """Single-head attention. q: (Tq, D), k: (Tk, D), v: (Tk, Dv) -> (Tq, Dv).

    Grid over query blocks; K/V stay VMEM-resident per grid point (edge
    sequence lengths are small). Scale = 1/sqrt(D).
    """
    tq, d = q.shape
    tk, d2 = k.shape
    tk2, dv = v.shape
    assert d == d2 and tk == tk2, (q.shape, k.shape, v.shape)
    bq = min(block_q, tq)
    while tq % bq != 0:
        bq -= 1
    grid = (tq // bq,)
    scale = 1.0 / float(d) ** 0.5
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((tk, d), lambda i: (0, 0)),
            pl.BlockSpec((tk, dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tq, dv), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


def self_attention_block(x: jax.Array, wq, wk, wv, wo) -> jax.Array:
    """Tiny transformer-ish self-attention block: x (T, D) -> (T, D).

    Projections use plain jnp matmuls (they lower into the same HLO); the
    attention core is the Pallas kernel above. Residual connection included.
    """
    q = x @ wq
    k = x @ wk
    v = x @ wv
    attn = attention(q, k, v)
    return x + attn @ wo
