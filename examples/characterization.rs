//! Characterization sweep — regenerates the motivation figures (Figs 2-6)
//! in one run: per-target PPW/latency, per-layer costs, precision/accuracy
//! trade-offs, interference and signal-strength shifts.
//!
//! Run: `cargo run --release --example characterization [--full]`

use autoscale::experiments;

fn main() -> anyhow::Result<()> {
    let quick = !std::env::args().any(|a| a == "--full");
    let seed = 7;
    for id in ["fig2", "fig3", "fig4", "fig5", "fig6"] {
        let tables = experiments::run_by_id(id, seed, quick)
            .ok_or_else(|| anyhow::anyhow!("missing experiment {id}"))?;
        for (i, t) in tables.iter().enumerate() {
            println!("{}", t.render());
            let slug = if tables.len() == 1 { id.to_string() } else { format!("{id}_{i}") };
            let path = t.write_csv(std::path::Path::new("reports"), &slug)?;
            println!("csv: {}\n", path.display());
        }
    }
    Ok(())
}
