//! End-to-end serving driver — the repository's E2E validation workload.
//!
//! Loads the real AOT model zoo (all 10 networks), trains AutoScale online
//! across static + dynamic environments with REAL PJRT execution grounding
//! the local targets, then evaluates frozen against every baseline and
//! reports PPW / latency percentiles / QoS compliance per policy.
//!
//! Run: `cargo run --release --example edge_serving` (see EXPERIMENTS.md
//! §E2E for a recorded run).

use autoscale::agent::qlearn::AutoScaleAgent;
use autoscale::configsys::runconfig::{EnvKind, RunConfig};
use autoscale::coordinator::envs::Environment;
use autoscale::coordinator::serve::{ServeConfig, Server};
use autoscale::policy::{AutoScalePolicy, CatalogueSpec, PolicySpec, ScalingPolicy};
use autoscale::runtime::Engine;
use autoscale::types::DeviceId;
use autoscale::util::stats;

fn main() -> anyhow::Result<()> {
    let t_start = std::time::Instant::now();
    let device = DeviceId::Mi8Pro;
    let seed = 7;

    // Real runtime over the full artifact zoo.
    let mut engine = Engine::from_default_manifest()?;
    println!("== AutoScale end-to-end edge serving ==");
    println!("PJRT platform  : {}", engine.platform());
    println!("artifact models: {:?}", engine.manifest().models().len());

    // ---- Phase 1: online training with real compute ----
    let catalogue = CatalogueSpec::new(device).build();
    let mut agent = AutoScaleAgent::new(catalogue, Default::default(), seed);
    let train_envs = [
        EnvKind::S1NoVariance,
        EnvKind::S2CpuHog,
        EnvKind::S3MemHog,
        EnvKind::S4WeakWlan,
        EnvKind::D2WebBrowser,
        EnvKind::D3RandomWlan,
    ];
    let mut trained_requests = 0usize;
    for (i, env) in train_envs.iter().enumerate() {
        let mut cfg = RunConfig::default();
        cfg.device = device;
        cfg.env = *env;
        cfg.seed = seed + i as u64;
        let environment = Environment::build(device, *env, seed + i as u64);
        let mut server = Server::new(
            environment,
            AutoScalePolicy::new(agent),
            ServeConfig { run: cfg, models: vec![] },
        )
        .with_engine(&mut engine);
        let m = server.serve(100);
        trained_requests += m.n();
        agent = server.policy.into_agent();
        println!(
            "train {}: {} reqs, PPW {:.2}, QoS misses {:.1}%",
            env.name(),
            m.n(),
            m.ppw(),
            m.qos_violation_ratio() * 100.0
        );
    }
    agent.freeze();
    println!(
        "trained {} updates over {} requests; q-table {} KB",
        agent.updates(),
        trained_requests,
        agent.table.memory_bytes() / 1024
    );

    // ---- Phase 2: frozen evaluation vs all baselines ----
    println!("\n{:16} {:>9} {:>10} {:>10} {:>10} {:>9}", "policy", "PPW", "p50 ms", "p95 ms", "QoS miss", "vs CPU");
    let mut cpu_ppw = None;
    for name in ["cpu", "best", "cloud", "connected", "autoscale", "opt"] {
        let mut all_lat = Vec::new();
        let mut total_energy = 0.0;
        let mut total_n = 0usize;
        let mut misses = 0usize;
        for (i, env) in [EnvKind::S1NoVariance, EnvKind::S3MemHog, EnvKind::D3RandomWlan]
            .iter()
            .enumerate()
        {
            let mut cfg = RunConfig::default();
            cfg.device = device;
            cfg.env = *env;
            cfg.seed = seed + 100 + i as u64;
            let environment = Environment::build(device, *env, seed + 100 + i as u64);
            // policies are consumed per-episode: rebuild each time, via the
            // registry for everything except the locally trained agent
            let p: Box<dyn ScalingPolicy> = match name {
                "autoscale" => {
                    let mut a = AutoScaleAgent::with_transfer(
                        agent.actions.clone(),
                        agent.params,
                        seed,
                        &agent,
                    );
                    a.freeze();
                    Box::new(AutoScalePolicy::new(a))
                }
                _ => autoscale::policy::build(name, &PolicySpec::new(device, seed))?,
            };
            let mut server = Server::new(environment, p, ServeConfig { run: cfg, models: vec![] })
                .with_engine(&mut engine);
            let m = server.serve(100);
            for o in &m.outcomes {
                all_lat.push(o.measurement.latency_s * 1e3);
                if o.qos_violated() {
                    misses += 1;
                }
            }
            total_energy += m.total_energy_j();
            total_n += m.n();
        }
        let ppw = total_n as f64 / total_energy;
        if name == "cpu" {
            cpu_ppw = Some(ppw);
        }
        println!(
            "{:16} {:>9.2} {:>10.2} {:>10.2} {:>9.1}% {:>8.2}x",
            name,
            ppw,
            stats::percentile(&all_lat, 50.0),
            stats::percentile(&all_lat, 95.0),
            100.0 * misses as f64 / total_n as f64,
            ppw / cpu_ppw.unwrap()
        );
    }
    println!("\ntotal wall time: {:.1}s", t_start.elapsed().as_secs_f64());
    Ok(())
}
