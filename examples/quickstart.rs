//! Quickstart: load a real AOT artifact through PJRT, train a small
//! AutoScale agent, and serve a handful of requests.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use autoscale::agent::qlearn::AutoScaleAgent;
use autoscale::configsys::runconfig::{EnvKind, RunConfig};
use autoscale::coordinator::envs::Environment;
use autoscale::coordinator::serve::{ServeConfig, Server};
use autoscale::policy::{AutoScalePolicy, CatalogueSpec};
use autoscale::runtime::Engine;
use autoscale::types::{DeviceId, Precision};

fn main() -> anyhow::Result<()> {
    // 1. Real compute: execute one AOT-compiled model on the PJRT CPU client.
    let mut engine = Engine::from_default_manifest()?;
    println!("PJRT platform : {}", engine.platform());
    let timing = engine.execute("mobilenet_v1", Precision::Fp32, 42)?;
    println!(
        "mobilenet_v1  : {:.2} ms wall, {} logits",
        timing.wall_s * 1e3,
        timing.output.len()
    );

    // 2. The AutoScale loop: observe -> select -> execute -> reward -> learn.
    let device = DeviceId::Mi8Pro;
    let catalogue = CatalogueSpec::new(device).build();
    println!("action space  : {} targets", catalogue.len());
    let agent = AutoScaleAgent::new(catalogue, Default::default(), 7);

    let mut cfg = RunConfig::default();
    cfg.device = device;
    let env = Environment::build(device, EnvKind::S1NoVariance, 7);
    let mut server = Server::new(
        env,
        AutoScalePolicy::new(agent),
        ServeConfig { run: cfg, models: vec!["mobilenet_v1", "inception_v1"] },
    )
    .with_engine(&mut engine);

    let metrics = server.serve(120);
    println!("served        : {} requests", metrics.n());
    println!("PPW           : {:.2} inferences/joule", metrics.ppw());
    println!("QoS misses    : {:.1}%", metrics.qos_violation_ratio() * 100.0);
    println!("selection mix :");
    let sel = metrics.selections();
    for bucket in autoscale::coordinator::metrics::SelectionStats::BUCKETS {
        let rate = sel.rate(bucket);
        if rate > 0.0 {
            println!("  {bucket:24} {:5.1}%", rate * 100.0);
        }
    }
    Ok(())
}
