//! Learning-transfer demo (Fig 14): train AutoScale on Mi8Pro, transfer
//! the Q-table to the other phones, and compare convergence speed against
//! training from scratch.
//!
//! Run: `cargo run --release --example train_transfer [--full]`

use autoscale::experiments::fig14_convergence;

fn main() -> anyhow::Result<()> {
    let quick = !std::env::args().any(|a| a == "--full");
    let tables = fig14_convergence::run(7, quick);
    for (i, t) in tables.iter().enumerate() {
        println!("{}", t.render());
        t.write_csv(std::path::Path::new("reports"), &format!("fig14_{i}"))?;
    }
    println!("(see reports/fig14_0.csv for the full reward curves)");
    Ok(())
}
